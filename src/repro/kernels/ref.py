"""Pure-jnp oracles for the Pallas kernels.

Independent implementations (no shared code with ``repro.core.isotonic`` or
the kernels) used by tests as ground truth:

* ``pav_l2_ref`` / ``pav_kl_ref``: the minimax characterization of isotonic
  regression,  v_i = min_{j<=i} max_{k>=i} gamma(y[j..k]),  vectorized as an
  O(n^2) interval-aggregate matrix.  Exact (same minimizer as PAV).
* ``soft_topk_gates_ref``: soft top-k via explicit permutahedron projection
  composed from the oracles above.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_NEG = -1e30


def _minimax(gamma: Array) -> Array:
  """v_i = min_{j<=i} max_{k>=i} gamma[..., j, k] (valid for j <= k)."""
  n = gamma.shape[-1]
  j = jnp.arange(n)[:, None]
  k = jnp.arange(n)[None, :]
  g = jnp.where(j <= k, gamma, _NEG)
  # inner[..., j, i] = max_{k >= i} g[..., j, k]: reverse cummax over k.
  inner = jnp.flip(
      jax.lax.cummax(jnp.flip(g, axis=-1), axis=g.ndim - 1), axis=-1)
  # v_i = min over j <= i of inner[..., j, i].
  masked = jnp.where(j <= k, inner, -_NEG)
  return jnp.min(masked, axis=-2)


def pav_l2_ref(y: Array) -> Array:
  """Isotonic regression (non-increasing fit) via minimax. Last axis."""
  n = y.shape[-1]
  j = jnp.arange(n)[:, None]
  k = jnp.arange(n)[None, :]
  # sums[.., j, k] = sum(y[j..k]) via a masked pairwise scan along k.
  # Costs log2(n) passes over the (n, n) matrix where a cumsum difference
  # is one pass, but avoids its cancellation error (cumsums grow to
  # O(n * max|y|) while interval sums stay small) — needed to keep the
  # minimax backend within 1e-5 of lax at soft-sort dynamic ranges.
  yk = jnp.broadcast_to(y[..., None, :], y.shape[:-1] + (n, n))
  g = jnp.where(j <= k, yk, jnp.zeros_like(yk))
  sums = jax.lax.associative_scan(jnp.add, g, axis=g.ndim - 1)
  length = jnp.maximum((k - j + 1), 1).astype(y.dtype)
  return _minimax(sums / length)


def pav_kl_ref(s: Array, w: Array) -> Array:
  """Entropic isotonic optimization via minimax on LSE-difference gammas."""
  n = s.shape[-1]
  j = jnp.arange(n)[:, None]
  k = jnp.arange(n)[None, :]

  def interval_lse(x: Array) -> Array:
    # interval_lse[..., j, k] = LSE(x[j..k]) via a masked logaddexp scan
    # along k.  A cumsum-of-exp difference would cancel catastrophically
    # for intervals far below the row max (exactly the regime soft-sort
    # hits: x = rho/eps spans n/eps); pairwise logaddexp is stable at any
    # dynamic range.
    xk = jnp.broadcast_to(x[..., None, :], x.shape[:-1] + (n, n))
    g = jnp.where(j <= k, xk, _NEG)
    return jax.lax.associative_scan(jnp.logaddexp, g, axis=g.ndim - 1)

  gamma = interval_lse(s) - interval_lse(w)
  return _minimax(gamma)


def soft_topk_gates_ref(
    logits: Array, k: int, regularization_strength: float = 1.0) -> Array:
  """Oracle for the fused router kernel: projection of logits/eps onto the
  k-subset permutahedron, composed from pav_l2_ref."""
  z = logits / regularization_strength
  n = z.shape[-1]
  w = jnp.concatenate(
      [jnp.ones((k,), z.dtype), jnp.zeros((n - k,), z.dtype)])
  sigma = jnp.argsort(-z, axis=-1, stable=True)
  s = jnp.take_along_axis(z, sigma, axis=-1)
  v = pav_l2_ref(s - jnp.broadcast_to(w, s.shape))
  out = jnp.zeros_like(v)
  out = jnp.put_along_axis(out, sigma, v, axis=-1, inplace=False)
  return z - out

"""Pallas TPU kernel: batched Pool-Adjacent-Violators (isotonic optimization).

TPU adaptation of the paper's §5 solver (see DESIGN.md §3): PAV is a
sequential, data-dependent stack machine — hostile to a 8x128 vector unit —
but every framework use-case is *batched* (rows = tokens / examples / loss
vectors).  The kernel therefore:

  * tiles rows into VMEM blocks (grid over row-tiles, BlockSpec (R, N));
  * runs the position loop once per tile with ALL rows advanced lane-wise:
    per-row stack tops are vectors, pops are masked vector selects, and the
    inner merge loop runs until every row in the tile has no violation
    (amortized O(n) per row, worst-case convoying bounded by the tile size);
  * expands block values back to positions with a second O(n) pointer sweep.

Both the quadratic (Eq. 7) and entropic (Eq. 8) block aggregates are
supported; the entropic variant tracks per-block log-sum-exps and merges
with logaddexp so it is exactly as stable as the reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_ROW_TILE = 8


def _take(arr: Array, idx: Array) -> Array:
  """arr: (R, N), idx: (R,) -> (R,) gather along axis 1 (clipped)."""
  idx = jnp.clip(idx, 0, arr.shape[1] - 1)
  return jnp.take_along_axis(arr, idx[:, None], axis=1)[:, 0]


def _put(arr: Array, idx: Array, val: Array) -> Array:
  return jnp.put_along_axis(
      arr, jnp.clip(idx, 0, arr.shape[1] - 1)[:, None], val[:, None],
      axis=1, inplace=False)


def _pav_body(y_like, init_cur, merge, block_value):
  """Shared stack machine. `y_like` drives shapes; callbacks define the
  aggregate algebra:

    init_cur(i)       -> tuple of (R,) registers for the singleton block {i}
    merge(cur, popped) -> merged registers
    block_value(regs) -> (R,) gamma value of a block

  Returns (starts (R,N), values (R,N), top (R,)).
  """
  r, n = y_like.shape
  num_regs = len(init_cur(0))
  regs0 = tuple(jnp.zeros((r, n), jnp.float32) for _ in range(num_regs))
  starts0 = jnp.zeros((r, n), jnp.int32)
  top0 = jnp.full((r,), -1, jnp.int32)

  def push(i, state):
    regs, starts, top = state
    cur = init_cur(i)
    cur_start = jnp.full((r,), i, jnp.int32)

    def violation(c):
      cur, cur_start, top = c
      top_regs = tuple(_take(a, top) for a in regs)
      return (top >= 0) & (
          block_value(top_regs) <= block_value(cur))

    def any_violation(c):
      return jnp.any(violation(c))

    def pop(c):
      cur, cur_start, top = c
      act = violation(c)
      top_regs = tuple(_take(a, top) for a in regs)
      merged = merge(cur, top_regs)
      cur = tuple(jnp.where(act, m, c_) for m, c_ in zip(merged, cur))
      cur_start = jnp.where(act, _take(starts, top), cur_start)
      top = jnp.where(act, top - 1, top)
      return cur, cur_start, top

    cur, cur_start, top = lax.while_loop(
        any_violation, pop, (cur, cur_start, top))
    top = top + 1
    regs = tuple(_put(a, top, v) for a, v in zip(regs, cur))
    starts = _put(starts, top, cur_start)
    return regs, starts, top

  regs, starts, top = lax.fori_loop(0, n, push, (regs0, starts0, top0))
  # Per-slot block values.
  vals = block_value(regs)  # elementwise over (R, N) slots
  return starts, vals, top


def _expand(starts: Array, vals: Array, top: Array, n: int) -> Array:
  """Blocks -> positions: O(n) pointer sweep (per-row current block slot)."""
  r = starts.shape[0]

  def step(p, carry):
    cur, out = carry
    nxt = _take(starts, cur + 1)
    adv = ((cur + 1) <= top) & (nxt == p)
    cur = jnp.where(adv, cur + 1, cur)
    col = _take(vals, cur)
    out = lax.dynamic_update_slice(out, col[:, None], (0, p))
    return cur, out

  cur0 = jnp.zeros((r,), jnp.int32)
  out0 = jnp.zeros((r, n), jnp.float32)
  _, out = lax.fori_loop(0, n, step, (cur0, out0))
  return out


def _pav_l2_kernel(y_ref, o_ref):
  y = y_ref[...].astype(jnp.float32)
  n = y.shape[1]

  starts, vals, top = _pav_body(
      y,
      init_cur=lambda i: (y[:, i], jnp.ones((y.shape[0],), jnp.float32)),
      merge=lambda cur, pop: (cur[0] + pop[0], cur[1] + pop[1]),
      block_value=lambda regs: regs[0] / jnp.maximum(regs[1], 1e-30),
  )
  o_ref[...] = _expand(starts, vals, top, n).astype(o_ref.dtype)


def _pav_kl_kernel(s_ref, w_ref, o_ref):
  s = s_ref[...].astype(jnp.float32)
  w = w_ref[...].astype(jnp.float32)
  n = s.shape[1]

  starts, vals, top = _pav_body(
      s,
      init_cur=lambda i: (s[:, i], w[:, i]),
      merge=lambda cur, pop: (jnp.logaddexp(cur[0], pop[0]),
                              jnp.logaddexp(cur[1], pop[1])),
      block_value=lambda regs: regs[0] - regs[1],
  )
  o_ref[...] = _expand(starts, vals, top, n).astype(o_ref.dtype)


def _call(kernel, args, row_tile: int, interpret: bool) -> Array:
  b, n = args[0].shape
  grid = (b // row_tile,)
  spec = pl.BlockSpec((row_tile, n), lambda i: (i, 0))
  return pl.pallas_call(
      kernel,
      out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
      grid=grid,
      in_specs=[spec] * len(args),
      out_specs=spec,
      interpret=interpret,
  )(*args)


def _pad_rows(x: Array, row_tile: int) -> tuple[Array, int]:
  b = x.shape[0]
  pad = (-b) % row_tile
  if pad:
    x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
  return x, b


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def pav_l2(y: Array, *, row_tile: int = DEFAULT_ROW_TILE,
           interpret: bool | None = None) -> Array:
  """Batched isotonic regression (non-increasing), y: (B, N) -> (B, N)."""
  if interpret is None:
    interpret = jax.default_backend() != "tpu"
  y32 = y.astype(jnp.float32)
  padded, b = _pad_rows(y32, row_tile)
  out = _call(_pav_l2_kernel, (padded,), row_tile, interpret)
  return out[:b].astype(y.dtype)


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def pav_kl(s: Array, w: Array, *, row_tile: int = DEFAULT_ROW_TILE,
           interpret: bool | None = None) -> Array:
  """Batched entropic isotonic optimization, (B, N) x (B, N) -> (B, N)."""
  if interpret is None:
    interpret = jax.default_backend() != "tpu"
  s32, w32 = s.astype(jnp.float32), w.astype(jnp.float32)
  ps, b = _pad_rows(s32, row_tile)
  pw, _ = _pad_rows(w32, row_tile)
  out = _call(_pav_kl_kernel, (ps, pw), row_tile, interpret)
  return out[:b].astype(s.dtype)

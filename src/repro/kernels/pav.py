"""Pallas TPU kernel: batched Pool-Adjacent-Violators (isotonic optimization).

TPU adaptation of the paper's §5 solver (see DESIGN.md §3): PAV is a
sequential, data-dependent stack machine — hostile to a 8x128 vector unit —
but every framework use-case is *batched* (rows = tokens / examples / loss
vectors).  The kernel therefore:

  * tiles rows into VMEM blocks (grid over row-tiles, BlockSpec (R, N));
  * runs the position loop once per tile with ALL rows advanced lane-wise:
    per-row stack tops are vectors, pops are masked vector selects, and the
    inner merge loop runs until every row in the tile has no violation
    (amortized O(n) per row, worst-case convoying bounded by the tile size);
  * expands block values back to positions with a second O(n) pointer sweep.

Both the quadratic (Eq. 7) and entropic (Eq. 8) block aggregates are
supported; the entropic variant tracks per-block log-sum-exps and merges
with logaddexp so it is exactly as stable as the reference.

The same lane-wise stack machine doubles as the ``"lax"`` reference backend
(``pav_l2_lax`` / ``pav_kl_lax``): it runs directly on the full (B, N) batch
as plain ``lax.fori_loop`` code, no ``pallas_call`` and no per-row vmap, so
the reference and the kernel share one implementation of the algorithm and
differ only in how rows are tiled onto the hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_ROW_TILE = 8

# VMEM budget per input block: the stack machine keeps ~6 (R, N) f32 arrays
# live (registers, starts, output), so bound R * N * 4 B * 6 by ~2 MiB.
_VMEM_BLOCK_BYTES = 2 * 1024 * 1024
_MAX_ROW_TILE = 256


def auto_row_tile(n: int, batch: int | None = None) -> int:
  """Largest power-of-two row tile whose working set fits the VMEM budget.

  May drop below the f32 sublane count (8) for very large n — Mosaic pads
  sub-sublane blocks internally, which wastes lanes but keeps the working
  set inside the budget instead of overflowing VMEM.  ``batch`` caps the
  tile so a small batch is never padded far past its own row count.
  """
  rows = max(1, _VMEM_BLOCK_BYTES // (6 * 4 * max(1, n)))
  tile = 1 << (rows.bit_length() - 1)
  if batch is not None and batch > 0:
    # next power of two >= batch
    tile = min(tile, 1 << (batch - 1).bit_length() if batch > 1 else 1)
  return int(min(_MAX_ROW_TILE, max(1, tile)))


def _take(arr: Array, idx: Array) -> Array:
  """arr: (R, N), idx: (R,) -> (R,) gather along axis 1 (clipped)."""
  idx = jnp.clip(idx, 0, arr.shape[1] - 1)
  return jnp.take_along_axis(arr, idx[:, None], axis=1)[:, 0]


def _put(arr: Array, idx: Array, val: Array) -> Array:
  return jnp.put_along_axis(
      arr, jnp.clip(idx, 0, arr.shape[1] - 1)[:, None], val[:, None],
      axis=1, inplace=False)


def _pav_body(y_like, init_cur, merge, block_value):
  """Shared stack machine. `y_like` drives shapes; callbacks define the
  aggregate algebra:

    init_cur(i)       -> tuple of (R,) registers for the singleton block {i}
    merge(cur, popped) -> merged registers
    block_value(regs) -> (R,) gamma value of a block

  Returns (starts (R,N), values (R,N), top (R,)).
  """
  r, n = y_like.shape
  num_regs = len(init_cur(0))
  regs0 = tuple(jnp.zeros((r, n), y_like.dtype) for _ in range(num_regs))
  starts0 = jnp.zeros((r, n), jnp.int32)
  top0 = jnp.full((r,), -1, jnp.int32)

  def push(i, state):
    regs, starts, top = state
    cur = init_cur(i)
    cur_start = jnp.full((r,), i, jnp.int32)

    def violation(c):
      cur, cur_start, top = c
      top_regs = tuple(_take(a, top) for a in regs)
      return (top >= 0) & (
          block_value(top_regs) <= block_value(cur))

    def any_violation(c):
      return jnp.any(violation(c))

    def pop(c):
      cur, cur_start, top = c
      act = violation(c)
      top_regs = tuple(_take(a, top) for a in regs)
      merged = merge(cur, top_regs)
      cur = tuple(jnp.where(act, m, c_) for m, c_ in zip(merged, cur))
      cur_start = jnp.where(act, _take(starts, top), cur_start)
      top = jnp.where(act, top - 1, top)
      return cur, cur_start, top

    cur, cur_start, top = lax.while_loop(
        any_violation, pop, (cur, cur_start, top))
    top = top + 1
    regs = tuple(_put(a, top, v) for a, v in zip(regs, cur))
    starts = _put(starts, top, cur_start)
    return regs, starts, top

  regs, starts, top = lax.fori_loop(0, n, push, (regs0, starts0, top0))
  # Per-slot block values.
  vals = block_value(regs)  # elementwise over (R, N) slots
  return starts, vals, top


def _expand(starts: Array, vals: Array, top: Array, n: int) -> Array:
  """Blocks -> positions: O(n) pointer sweep (per-row current block slot)."""
  r = starts.shape[0]

  def step(p, carry):
    cur, out = carry
    nxt = _take(starts, cur + 1)
    adv = ((cur + 1) <= top) & (nxt == p)
    cur = jnp.where(adv, cur + 1, cur)
    col = _take(vals, cur)
    out = lax.dynamic_update_slice(out, col[:, None], (0, p))
    return cur, out

  cur0 = jnp.zeros((r,), jnp.int32)
  out0 = jnp.zeros((r, n), vals.dtype)
  _, out = lax.fori_loop(0, n, step, (cur0, out0))
  return out


def _pav_l2_kernel(y_ref, o_ref):
  y = y_ref[...].astype(jnp.float32)
  n = y.shape[1]

  starts, vals, top = _pav_body(
      y,
      init_cur=lambda i: (y[:, i], jnp.ones((y.shape[0],), jnp.float32)),
      merge=lambda cur, pop: (cur[0] + pop[0], cur[1] + pop[1]),
      block_value=lambda regs: regs[0] / jnp.maximum(regs[1], 1e-30),
  )
  o_ref[...] = _expand(starts, vals, top, n).astype(o_ref.dtype)


def _pav_kl_kernel(s_ref, w_ref, o_ref):
  s = s_ref[...].astype(jnp.float32)
  w = w_ref[...].astype(jnp.float32)
  n = s.shape[1]

  starts, vals, top = _pav_body(
      s,
      init_cur=lambda i: (s[:, i], w[:, i]),
      merge=lambda cur, pop: (jnp.logaddexp(cur[0], pop[0]),
                              jnp.logaddexp(cur[1], pop[1])),
      block_value=lambda regs: regs[0] - regs[1],
  )
  o_ref[...] = _expand(starts, vals, top, n).astype(o_ref.dtype)


def _call(kernel, args, row_tile: int, interpret: bool) -> Array:
  b, n = args[0].shape
  grid = (b // row_tile,)
  spec = pl.BlockSpec((row_tile, n), lambda i: (i, 0))
  return pl.pallas_call(
      kernel,
      out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
      grid=grid,
      in_specs=[spec] * len(args),
      out_specs=spec,
      interpret=interpret,
  )(*args)


def _pad_rows(x: Array, row_tile: int) -> tuple[Array, int]:
  b = x.shape[0]
  pad = (-b) % row_tile
  if pad:
    x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
  return x, b


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def pav_l2(y: Array, *, row_tile: int | None = None,
           interpret: bool | None = None) -> Array:
  """Batched isotonic regression (non-increasing), y: (B, N) -> (B, N).

  ``row_tile=None`` picks the largest VMEM-safe batch tile for N.
  """
  if interpret is None:
    interpret = jax.default_backend() != "tpu"
  if row_tile is None:
    row_tile = auto_row_tile(y.shape[-1], y.shape[0])
  y32 = y.astype(jnp.float32)
  padded, b = _pad_rows(y32, row_tile)
  out = _call(_pav_l2_kernel, (padded,), row_tile, interpret)
  return out[:b].astype(y.dtype)


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def pav_kl(s: Array, w: Array, *, row_tile: int | None = None,
           interpret: bool | None = None) -> Array:
  """Batched entropic isotonic optimization, (B, N) x (B, N) -> (B, N)."""
  if interpret is None:
    interpret = jax.default_backend() != "tpu"
  if row_tile is None:
    row_tile = auto_row_tile(s.shape[-1], s.shape[0])
  s32, w32 = s.astype(jnp.float32), w.astype(jnp.float32)
  ps, b = _pad_rows(s32, row_tile)
  pw, _ = _pad_rows(w32, row_tile)
  out = _call(_pav_kl_kernel, (ps, pw), row_tile, interpret)
  return out[:b].astype(s.dtype)


# ---------------------------------------------------------------------------
# "lax" reference backend: the same stack machine, no pallas_call.
# ---------------------------------------------------------------------------


@jax.jit
def pav_l2_lax(y: Array) -> Array:
  """Batched isotonic regression on (B, N) via the plain-lax stack machine."""
  # float64 inputs (x64 mode) keep full precision; halves compute in f32.
  yc = y.astype(jnp.promote_types(y.dtype, jnp.float32))
  starts, vals, top = _pav_body(
      yc,
      init_cur=lambda i: (yc[:, i], jnp.ones((yc.shape[0],), yc.dtype)),
      merge=lambda cur, pop: (cur[0] + pop[0], cur[1] + pop[1]),
      block_value=lambda regs: regs[0] / jnp.maximum(regs[1], 1e-30),
  )
  return _expand(starts, vals, top, y.shape[-1]).astype(y.dtype)


@jax.jit
def pav_kl_lax(s: Array, w: Array) -> Array:
  """Batched entropic isotonic optimization on (B, N), plain-lax machine."""
  dt = jnp.promote_types(s.dtype, jnp.float32)
  sc, wc = s.astype(dt), w.astype(dt)
  starts, vals, top = _pav_body(
      sc,
      init_cur=lambda i: (sc[:, i], wc[:, i]),
      merge=lambda cur, pop: (jnp.logaddexp(cur[0], pop[0]),
                              jnp.logaddexp(cur[1], pop[1])),
      block_value=lambda regs: regs[0] - regs[1],
  )
  return _expand(starts, vals, top, s.shape[-1]).astype(s.dtype)

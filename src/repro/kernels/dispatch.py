"""Backend dispatch for the batched isotonic/projection stack.

Single choke point through which every soft-sort/rank pass routes: a
*forward* registry mapping ``(op, regularization, backend)`` ->
implementation, and a *backward* registry mapping
``(op, regularization, backward_backend)`` -> VJP implementation.  All
registered implementations share the same contract — they take f32-safe
arrays whose *last* axis is the problem dimension, flattened here to
``(rows, n)``, and return the same shape.  The promote-compute-demote
dtype contract is enforced *here*, uniformly: half-precision floating
inputs (bf16/f16) are promoted to f32 before any backend sees them and
the result is cast back, for every backend and both directions — no
backend carries its own casting wrapper.

Forward backends
----------------
* ``"lax"``      reference ``lax.fori_loop`` stack machine, natively batched
                 (``repro.kernels.pav.pav_l2_lax`` / ``pav_kl_lax``);
                 O(n) work per row but O(n) *sequential depth*.
* ``"scan"``     divide-and-conquer PAV (``repro.kernels.pav_scan``):
                 log2(n) vectorized merge levels — O(n log n) work at
                 O(log n) depth, the paper's complexity claim realized on
                 depth-dominated hardware (CPU/GPU).
* ``"pallas"``   tiled TPU kernel (``repro.kernels.pav``); interpret mode
                 off-TPU, so it is usable (slowly) everywhere.
* ``"minimax"``  O(n^2) vectorized closed form (``repro.kernels.ref``) with
                 zero data-dependent control flow — the right trade for
                 small n and under SPMD.
* ``"auto"``     defers the choice to the execution-plan chain (below).

Backward backends
-----------------
The exact O(n) segment-algebra VJP (paper Lemma 2) has two registered
formulations (``repro.kernels.segment_vjp``): ``"segscan"`` (default;
segmented prefix scans + block-end gathers, scatter-free) and
``"scatter"`` (the original ``segment_sum`` over globally-offset ids).

Selection: ONE precedence chain for all three decision kinds (forward
backend, backward backend, projection path)::

    explicit argument (``impl=`` / ``backend=`` / ``path=``)
      > environment (REPRO_BACKEND / REPRO_BACKWARD / REPRO_PROJECTION)
      > execution plan (per-call ``plan=`` or the active ``use_plan`` /
        ``set_active_plan`` plan)
      > packaged default plan (src/repro/plan/default_plan.json,
        emitted by tools/autotune.py from measured BENCH sweeps)
      > built-in plan (repro.plan.builtin_plan: TPU -> pallas, small-n
        minimax under a memory cap, scan otherwise; segscan; fused)

``"auto"`` — as an argument or environment value — means "fall through
to the plan chain".  Resolution is deterministic given (request,
environment, plans, platform, dtype, shape): the same inputs always pick
the same implementation, so a jit cache entry never flips backends
between traces.  The legacy ``use_backend`` / ``use_backward`` /
``set_default_backend`` entry points survive as thin shims that install
an overriding rule on the active plan.

Observability: every resolution and every dispatched call (forward and
backward) is recorded into ``repro.obs.metrics`` (counters keyed by
``(op, regularization, backend)``, shape buckets, per-plan decision
counters ``plan_decide{kind,backend,source,plan}``, and bounded
trace-cache hit/miss/eviction counts), and every backend call runs under
a ``jax.named_scope`` so kernels are attributable in jaxprs / HLO
metadata / ``jax.profiler`` traces.  All of this happens at Python trace
time only, and is a no-op when metrics are disabled (``REPRO_METRICS=0``).
"""

from __future__ import annotations

import contextlib
import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro import plan as _plan
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

Array = jax.Array
ExecutionPlan = _plan.ExecutionPlan

ENV_VAR = "REPRO_BACKEND"
BWD_ENV_VAR = "REPRO_BACKWARD"
PROJECTION_ENV_VAR = "REPRO_PROJECTION"

BACKENDS = ("auto", "lax", "scan", "pallas", "minimax")
BWD_BACKENDS = ("auto", "segscan", "scatter")
PROJECTION_PATHS = ("auto", "fused", "composed")

# Backwards-compatible aliases for the (former) hardcoded auto cutoffs;
# the authoritative values now live in the built-in plan
# (repro.plan.builtin_plan) as ordinary shape-bucket rule entries.
AUTO_MINIMAX_MAX_N = _plan.BUILTIN_MINIMAX_MAX_N
AUTO_MINIMAX_MAX_ELEMS = _plan.BUILTIN_MINIMAX_MAX_ELEMS

_REGISTRY: dict[tuple[str, str, str], Callable[..., Array]] = {}
_BWD_REGISTRY: dict[tuple[str, str, str], Callable[..., tuple]] = {}

# One spec per decision kind: env var, allowed request values, and the
# metrics counter each resolution records under.
_KIND_SPECS = {
    "forward": (ENV_VAR, BACKENDS, "dispatch_resolve"),
    "backward": (BWD_ENV_VAR, BWD_BACKENDS, "dispatch_bwd_resolve"),
    "projection": (PROJECTION_ENV_VAR, PROJECTION_PATHS,
                   "projection_resolve"),
}

_HALF_DTYPES = (jnp.bfloat16, jnp.float16)


def register(op: str, regularization: str, backend: str):
  """Decorator: register ``fn`` as the (op, regularization, backend) impl."""

  def deco(fn: Callable[..., Array]) -> Callable[..., Array]:
    _REGISTRY[(op, regularization, backend)] = fn
    return fn

  return deco


def register_backward(op: str, regularization: str, backend: str):
  """Decorator: register a VJP impl under (op, regularization, backend)."""

  def deco(fn: Callable[..., tuple]) -> Callable[..., tuple]:
    _BWD_REGISTRY[(op, regularization, backend)] = fn
    return fn

  return deco


def registered_backends(op: str, regularization: str) -> tuple[str, ...]:
  """Concrete (non-auto) backends registered for an (op, regularization)."""
  return tuple(b for (o, r, b) in _REGISTRY
               if o == op and r == regularization)


def registered_backward_backends(
    op: str, regularization: str) -> tuple[str, ...]:
  """Concrete backward backends registered for an (op, regularization)."""
  return tuple(b for (o, r, b) in _BWD_REGISTRY
               if o == op and r == regularization)


# ---------------------------------------------------------------------------
# Plan-based selection state + legacy shims.
# ---------------------------------------------------------------------------

# Re-exported so callers can keep importing selection tools from the
# dispatch choke point.
use_plan = _plan.use_plan
set_active_plan = _plan.set_active_plan
get_active_plan = _plan.get_active_plan
load_plan = _plan.load_plan


def _override_plan(kind: str, backend: str) -> ExecutionPlan:
  """Active plan with an unconditional ``kind -> backend`` rule prepended
  (``"auto"`` instead *removes* any unconditional override of that kind,
  restoring fall-through to the default plans)."""
  base = _plan.get_active_plan()
  base_rules = base.rules if base is not None else ()
  if backend == "auto":
    rules = tuple(r for r in base_rules
                  if not (r.kind == kind and not r.shape_constrained()
                          and r.op == "*" and r.regularization == "*"
                          and r.platform == "*" and r.dtype == "*"))
  else:
    rules = (_plan.PlanRule(kind, backend),) + tuple(base_rules)
  name = f"{base.name if base is not None else 'override'}+{kind}={backend}"
  return ExecutionPlan(name=name, rules=rules)


def _unconditional_choice(kind: str) -> str:
  """Backend of the first fully-unconditional active-plan rule of ``kind``
  (the legacy 'process default'), or ``"auto"`` when none is installed."""
  base = _plan.get_active_plan()
  for r in (base.rules if base is not None else ()):
    if (r.kind == kind and not r.shape_constrained() and r.op == "*"
        and r.regularization == "*" and r.platform == "*"
        and r.dtype == "*"):
      return r.backend
  return "auto"


def get_default_backend() -> str:
  """Deprecated shim: the active plan's unconditional forward override."""
  return _unconditional_choice("forward")


def set_default_backend(backend: str) -> None:
  """Deprecated shim over ``set_active_plan``: installs an unconditional
  forward-backend rule on the active plan (``"auto"`` removes it)."""
  if backend not in BACKENDS:
    raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
  _plan.set_active_plan(_override_plan("forward", backend))


@contextlib.contextmanager
def use_backend(backend: str):
  """Deprecated shim over ``use_plan``: scoped unconditional forward rule
  (trace-time only: custom_vjp fwd rules are traced lazily, so pass
  ``backend=`` explicitly under jit)."""
  if backend not in BACKENDS:
    raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
  with _plan.use_plan(_override_plan("forward", backend)):
    yield


def get_default_backward() -> str:
  """Deprecated shim: the active plan's unconditional backward override."""
  return _unconditional_choice("backward")


def set_default_backward(backend: str) -> None:
  """Deprecated shim: unconditional backward rule on the active plan."""
  if backend not in BWD_BACKENDS:
    raise ValueError(
        f"backward backend must be one of {BWD_BACKENDS}, got {backend!r}")
  _plan.set_active_plan(_override_plan("backward", backend))


@contextlib.contextmanager
def use_backward(backend: str):
  """Deprecated shim over ``use_plan`` for the backward (VJP) formulation
  (trace-time only: like ``use_backend``, custom_vjp bwd rules are traced
  lazily under jit — eager/top-level ``jax.grad`` calls are the reliable
  use)."""
  if backend not in BWD_BACKENDS:
    raise ValueError(
        f"backward backend must be one of {BWD_BACKENDS}, got {backend!r}")
  with _plan.use_plan(_override_plan("backward", backend)):
    yield


def _env_choice(env_var: str, allowed: tuple[str, ...]) -> str | None:
  """Validated environment backend value, or None when unset/empty.

  Validated at read time: an unknown value would otherwise surface much
  later as a confusing registry KeyError deep inside a traced call.
  """
  raw = os.environ.get(env_var)
  if not raw:
    return None
  if raw not in allowed:
    raise ValueError(
        f"{env_var}={raw!r} is not a known backend; "
        f"expected one of {allowed}")
  return raw


def resolve(
    kind: str,
    op: str,
    regularization: str,
    request: str | None = None,
    *,
    shape: tuple[int, ...] | None = None,
    platform: str | None = None,
    dtype: str | None = None,
    plan: ExecutionPlan | None = None,
) -> str:
  """THE precedence chain, shared by all three decision kinds.

  ``explicit request > environment > plan (arg/active) > packaged
  default plan > built-in plan``; a request or environment value of
  ``"auto"`` falls through to the plan chain.  Deterministic given its
  inputs, so a jit cache entry never flips backends between traces.
  """
  env_var, allowed, counter = _KIND_SPECS[kind]
  if request and request != "auto":
    if request not in allowed:
      # Tolerate registered-but-unlisted names (an out-of-tree backend
      # registered via ``register``): the registry check below is the
      # real gate; ``allowed`` only vets the built-in spelling set.
      known = _registered_for(kind, op, regularization)
      if request not in known:
        raise ValueError(
            f"no {kind} backend {request!r} for op={op!r}, "
            f"regularization={regularization!r}; have {known}")
    b, source = request, "arg"
  else:
    env = _env_choice(env_var, allowed)
    if env and env != "auto":
      b, source = env, "env"
    else:
      platform = platform or jax.default_backend()
      b, source, _ = _plan.resolve_via_plans(
          kind, op, regularization, platform=platform,
          dtype=dtype or "*", shape=shape, plan=plan)
  _check_registered(kind, op, regularization, b)
  _metrics.counter_inc(counter, op=op, regularization=regularization,
                       backend=b, source=source)
  return b


def _registered_for(kind: str, op: str,
                    regularization: str) -> tuple[str, ...]:
  if kind == "backward":
    return registered_backward_backends(op, regularization)
  return registered_backends(op, regularization)


def _check_registered(kind: str, op: str, regularization: str,
                      backend: str) -> None:
  if kind == "projection":
    # The projection registry is populated on repro.core.projection
    # import; dispatch_projection does its own lookup with a pointer to
    # that import, and reg-less queries (bench meta) have no key to check.
    return
  reg_map = _BWD_REGISTRY if kind == "backward" else _REGISTRY
  if (op, regularization, backend) not in reg_map:
    raise ValueError(
        f"no {kind} backend {backend!r} registered for op={op!r}, "
        f"regularization={regularization!r}; have "
        f"{_registered_for(kind, op, regularization)}")


def resolve_backend(
    op: str,
    regularization: str,
    backend: str | None = None,
    *,
    shape: tuple[int, ...] | None = None,
    platform: str | None = None,
    dtype: str | None = None,
    plan: ExecutionPlan | None = None,
) -> str:
  """Resolve a forward-backend request through the unified chain."""
  return resolve("forward", op, regularization, backend, shape=shape,
                 platform=platform, dtype=dtype, plan=plan)


def resolve_backward(
    op: str,
    regularization: str,
    backend: str | None = None,
    *,
    shape: tuple[int, ...] | None = None,
    platform: str | None = None,
    dtype: str | None = None,
    plan: ExecutionPlan | None = None,
) -> str:
  """Resolve a backward (VJP) backend request through the unified chain."""
  return resolve("backward", op, regularization, backend, shape=shape,
                 platform=platform, dtype=dtype, plan=plan)


def resolve_projection(
    path: str | None = None,
    regularization: str | None = None,
    *,
    shape: tuple[int, ...] | None = None,
    platform: str | None = None,
    dtype: str | None = None,
    plan: ExecutionPlan | None = None,
) -> str:
  """Resolve a projection-path request through the unified chain.

  The projection registry (``("projection", reg, path)`` keys, populated
  on ``repro.core.projection`` import) holds whole-pipeline
  implementations: ``"fused"`` — single custom VJP around sort + isotonic
  solve + gather, packed integer sorts, gather-only backward;
  ``"composed"`` — the reference chain of four differentiable primitives,
  kept reachable (env/plan ``composed``) for differential testing.
  """
  return resolve("projection", "projection", regularization, path,
                 shape=shape, platform=platform, dtype=dtype, plan=plan)


# Trace-key cache: (op, reg, backend, flat shape, dtype) tuples already seen
# by ``dispatch``.  A repeated key means jit served the call from its
# compile cache (or re-traced an identical signature); a new key is a fresh
# trace/compile.  Only mutated while metrics are enabled, and cleared with
# the registry, so disabled mode retains no state.  Bounded: a long-running
# server seeing unboundedly many distinct shapes (launch/serve.py ragged
# batches) must not leak one tuple per shape forever, so insertion order is
# tracked and the oldest key is evicted at the cap (the eviction count is
# itself a metric — a hot eviction counter means the cache is thrashing and
# hit/miss ratios undercount true jit cache hits).
TRACE_KEY_CAP = 4096
_SEEN_TRACE_KEYS: dict[tuple, None] = {}
_metrics.on_reset(_SEEN_TRACE_KEYS.clear)


def _trace_cache_note(key: tuple) -> None:
  """Record hit/miss for a dispatch trace key, evicting at the cap."""
  if key in _SEEN_TRACE_KEYS:
    _metrics.counter_inc("dispatch_trace_cache_hit")
    return
  while len(_SEEN_TRACE_KEYS) >= TRACE_KEY_CAP:
    _SEEN_TRACE_KEYS.pop(next(iter(_SEEN_TRACE_KEYS)))
    _metrics.counter_inc("dispatch_trace_cache_evict")
  _SEEN_TRACE_KEYS[key] = None
  _metrics.counter_inc("dispatch_trace_cache_miss")


def _promote_flat(args: tuple[Array, ...], n: int):
  """Flatten to (rows, n) and apply the uniform promote-compute contract:
  every inexact (floating/complex) argument below f32 is promoted to f32;
  integer/bool structure arrays pass through untouched.  Returns the flat
  list plus the original inexact dtype to demote results back to (None
  when no argument was inexact)."""
  inexact = [a.dtype for a in args if jnp.issubdtype(a.dtype, jnp.inexact)]
  orig = jnp.result_type(*inexact) if inexact else None
  flat = []
  for a in args:
    f = a.reshape(-1, n)
    if jnp.issubdtype(a.dtype, jnp.inexact):
      f = f.astype(jnp.promote_types(a.dtype, jnp.float32))
    flat.append(f)
  return flat, orig


def dispatch_projection(z: Array, w: Array, regularization: str,
                        impl: str | None, path: str | None = None,
                        plan: ExecutionPlan | None = None,
                        **kwargs) -> Array:
  """Route a permutahedron projection to the fused or composed pipeline.

  Unlike ``dispatch``, implementations here own their batching (the fused
  path needs the unflattened unbatched-``w`` shape to share one weight
  sort across the batch), so ``z``/``w`` pass through unflattened;
  ``kwargs`` carry the static sortedness flags and optional precomputed
  permutations.  Runs under a ``repro_projection_<reg>_<path>`` named
  scope; fused calls are counted as ``projection_fused_calls``.
  """
  p = resolve_projection(path, regularization, shape=z.shape,
                         dtype=str(z.dtype), plan=plan)
  fn = _REGISTRY.get(("projection", regularization, p))
  if fn is None:
    raise ValueError(
        f"no projection path {p!r} registered for "
        f"regularization={regularization!r} (import repro.core.projection); "
        f"have {registered_backends('projection', regularization)}")
  if p == "fused":
    _metrics.counter_inc("projection_fused_calls",
                         regularization=regularization)
  _metrics.counter_inc("dispatch_calls", op="projection",
                       regularization=regularization, backend=p)
  with _tracing.backend_scope("projection", regularization, p):
    return fn(z, w, impl, plan=plan, **kwargs)


def dispatch(op: str, regularization: str, backend: str | None,
             *args: Array, plan: ExecutionPlan | None = None) -> Array:
  """Route a batched forward pass to the resolved backend.

  All ``args`` must share a common shape whose last axis is the problem
  dimension; leading batch axes are flattened to a single row axis before
  the backend call and restored afterwards, so backends only ever see
  (rows, n).  Half-precision inputs are promoted to f32 for the solve and
  the result demoted back — uniformly, for every backend.

  The backend call runs under ``jax.named_scope`` (see
  ``repro.obs.tracing.scope_name``) so its primitives are attributable in
  profiler traces, and — when metrics are enabled — records per-backend
  call counts, flattened shape buckets, and trace-cache hit/miss counters.
  """
  shape = args[0].shape
  in_dtype = str(jnp.result_type(args[0]))
  b = resolve_backend(op, regularization, backend, shape=shape,
                      dtype=in_dtype, plan=plan)
  fn = _REGISTRY[(op, regularization, b)]
  n = shape[-1]
  flat, orig_dtype = _promote_flat(args, n)
  if _metrics.enabled():
    rows = flat[0].shape[0] if n else 0
    _metrics.counter_inc("dispatch_calls", op=op,
                         regularization=regularization, backend=b)
    _metrics.counter_inc("dispatch_shape", op=op,
                         bucket=_metrics.shape_bucket(rows, n))
    _trace_cache_note((op, regularization, b, flat[0].shape, in_dtype))
  with _tracing.backend_scope(op, regularization, b):
    out = fn(*flat)
  if orig_dtype is not None:
    out = out.astype(orig_dtype)
  return out.reshape(shape)


def dispatch_backward(op: str, regularization: str, backend: str | None,
                      *args: Array, plan: ExecutionPlan | None = None):
  """Route a batched VJP to the resolved backward backend.

  Same flattening and promote-compute-demote contract as ``dispatch``
  (integer/bool segment-structure arrays pass through unpromoted); the
  impl may return a single gradient array or a tuple of gradient arrays
  (each is restored to the original batch shape).  Runs under a
  ``repro_<op>_bwd_<reg>_<backend>`` named scope and records
  ``dispatch_bwd_calls`` counters.
  """
  shape = args[0].shape
  b = resolve_backward(op, regularization, backend, shape=shape,
                       dtype=str(jnp.result_type(args[0])), plan=plan)
  fn = _BWD_REGISTRY[(op, regularization, b)]
  n = shape[-1]
  flat, orig_dtype = _promote_flat(args, n)
  _metrics.counter_inc("dispatch_bwd_calls", op=op,
                       regularization=regularization, backend=b)
  with _tracing.backend_scope(f"{op}_bwd", regularization, b):
    out = fn(*flat)
  if isinstance(out, tuple):
    if orig_dtype is not None:
      out = tuple(o.astype(orig_dtype) for o in out)
    return tuple(o.reshape(shape) for o in out)
  if orig_dtype is not None:
    out = out.astype(orig_dtype)
  return out.reshape(shape)


# ---------------------------------------------------------------------------
# Jit-stable entry points.
# ---------------------------------------------------------------------------

_STABLE_ENTRIES: dict[tuple, Callable] = {}
_STABLE_DISPATCHERS = {"forward": dispatch, "backward": dispatch_backward}


def stable_entry(op: str, regularization: str, backend: str | None = None,
                 *, kind: str = "forward",
                 plan: ExecutionPlan | None = None) -> Callable[..., Array]:
  """A process-stable callable for one pinned dispatch configuration.

  ``jax.jit`` keys its trace cache on function identity, and AOT callers
  (``jax.jit(fn).lower(...).compile()``, the serving engine's executable
  cache) need a deterministic function object per configuration — an
  ad-hoc ``lambda``/``partial`` built at the call site defeats both.
  This returns *the same* callable object for the same
  ``(kind, op, regularization, backend, plan)`` every time:

      f = stable_entry("isotonic", "l2", "scan")
      f is stable_entry("isotonic", "l2", "scan")   # True
      jax.jit(f)(y)        # hits the jit cache across call sites
      jax.jit(f).lower(spec).compile()              # AOT-friendly

  ``kind`` is ``"forward"`` (:func:`dispatch`) or ``"backward"``
  (:func:`dispatch_backward`); the pinned args follow those functions'
  signatures, so the returned callable takes the dispatch ``*args``.
  """
  if kind not in _STABLE_DISPATCHERS:
    raise ValueError(f"kind must be one of "
                     f"{tuple(_STABLE_DISPATCHERS)}, got {kind!r}")
  key = (kind, op, regularization, backend,
         None if plan is None else plan.plan_hash())
  fn = _STABLE_ENTRIES.get(key)
  if fn is None:
    fn = functools.partial(_STABLE_DISPATCHERS[kind], op, regularization,
                           backend, plan=plan)
    _STABLE_ENTRIES[key] = fn
  return fn


# ---------------------------------------------------------------------------
# Backend registration (isotonic optimization, paper §5).
# ---------------------------------------------------------------------------

from repro.kernels import pav as _pav  # noqa: E402
from repro.kernels import pav_scan as _pav_scan  # noqa: E402
from repro.kernels import ref as _ref  # noqa: E402
from repro.kernels import segment_vjp as _svjp  # noqa: E402

register("isotonic", "l2", "lax")(_pav.pav_l2_lax)
register("isotonic", "kl", "lax")(_pav.pav_kl_lax)

register("isotonic", "l2", "scan")(_pav_scan.pav_l2_scan)
register("isotonic", "kl", "scan")(_pav_scan.pav_kl_scan)

register("isotonic", "l2", "pallas")(_pav.pav_l2)
register("isotonic", "kl", "pallas")(_pav.pav_kl)

# No per-backend casting wrappers: ``dispatch`` owns the uniform
# promote-compute-demote contract, so the O(n^2) closed forms register
# bare like every other backend.
register("isotonic", "l2", "minimax")(_ref.pav_l2_ref)
register("isotonic", "kl", "minimax")(_ref.pav_kl_ref)

register_backward("isotonic", "l2", "segscan")(_svjp.isotonic_l2_bwd_segscan)
register_backward("isotonic", "l2", "scatter")(_svjp.isotonic_l2_bwd_scatter)
register_backward("isotonic", "kl", "segscan")(_svjp.isotonic_kl_bwd_segscan)
register_backward("isotonic", "kl", "scatter")(_svjp.isotonic_kl_bwd_scatter)

# Fused-projection backward table: same Lemma 2 segment algebra, consuming
# the block structure precomputed by the fused forward (residuals) instead
# of re-deriving it from the solver output.  Forward projection paths
# ("fused" / "composed") register themselves on ``repro.core.projection``
# import — kernels must not import core.
register_backward("projection", "l2",
                  "segscan")(_svjp.projection_l2_bwd_segscan)
register_backward("projection", "l2",
                  "scatter")(_svjp.projection_l2_bwd_scatter)
register_backward("projection", "kl",
                  "segscan")(_svjp.projection_kl_bwd_segscan)
register_backward("projection", "kl",
                  "scatter")(_svjp.projection_kl_bwd_scatter)

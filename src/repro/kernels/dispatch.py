"""Backend dispatch for the batched isotonic/projection stack.

Single choke point through which every soft-sort/rank forward pass routes:
a registry mapping ``(op, regularization, backend)`` -> implementation.
All registered implementations share the same contract — they take f32-safe
arrays whose *last* axis is the problem dimension, flattened here to
``(rows, n)``, and return the same shape — and they all share the exact
O(n) segment-algebra VJP defined in ``repro.core.isotonic`` (the registry
only ever dispatches forward passes).

Backends
--------
* ``"lax"``      reference ``lax.fori_loop`` stack machine, natively batched
                 (``repro.kernels.pav.pav_l2_lax`` / ``pav_kl_lax``).
* ``"pallas"``   tiled TPU kernel (``repro.kernels.pav``); interpret mode
                 off-TPU, so it is usable (slowly) everywhere.
* ``"minimax"``  O(n^2) vectorized closed form (``repro.kernels.ref``) with
                 zero data-dependent control flow — the right trade for
                 small n and under SPMD.
* ``"auto"``     resolves deterministically from platform and shape at trace
                 time: TPU -> ``"pallas"``; otherwise ``"minimax"`` for
                 small problems (n <= 64 and rows * n^2 bounded) else
                 ``"lax"``.

Selection precedence: explicit ``backend=`` argument > ``REPRO_BACKEND``
environment variable > ``set_default_backend`` / ``use_backend`` (process
default, initially ``"auto"``).

Observability: every resolution and every dispatched call is recorded into
``repro.obs.metrics`` (counters keyed by ``(op, regularization, backend)``,
shape buckets, auto-routing decisions, and trace-cache hit/miss counts),
and every backend forward runs under a ``jax.named_scope`` so kernels are
attributable in jaxprs / HLO metadata / ``jax.profiler`` traces.  All of
this happens at Python trace time only, and is a no-op when metrics are
disabled (``REPRO_METRICS=0``).
"""

from __future__ import annotations

import contextlib
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

Array = jax.Array

ENV_VAR = "REPRO_BACKEND"

BACKENDS = ("auto", "lax", "pallas", "minimax")

# n at or below which the O(n^2) closed form beats the sequential machine
# off-TPU (no while_loop, trivially vectorized; memory is rows * n^2 floats).
AUTO_MINIMAX_MAX_N = 64

# Cap on rows * n^2 f32 elements for auto-selecting minimax (~64 MB): a
# large flattened batch at small n (the MoE-router regime) must fall back
# to the O(rows * n) lax machine instead of materializing rows (n, n)
# matrices.
AUTO_MINIMAX_MAX_ELEMS = 16_000_000

_REGISTRY: dict[tuple[str, str, str], Callable[..., Array]] = {}

_DEFAULT = {"value": "auto"}


def register(op: str, regularization: str, backend: str):
  """Decorator: register ``fn`` as the (op, regularization, backend) impl."""

  def deco(fn: Callable[..., Array]) -> Callable[..., Array]:
    _REGISTRY[(op, regularization, backend)] = fn
    return fn

  return deco


def registered_backends(op: str, regularization: str) -> tuple[str, ...]:
  """Concrete (non-auto) backends registered for an (op, regularization)."""
  return tuple(b for (o, r, b) in _REGISTRY
               if o == op and r == regularization)


def get_default_backend() -> str:
  return _DEFAULT["value"]


def set_default_backend(backend: str) -> None:
  if backend not in BACKENDS:
    raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
  _DEFAULT["value"] = backend


@contextlib.contextmanager
def use_backend(backend: str):
  """Temporarily select the default backend (trace-time only: custom_vjp
  fwd rules are traced lazily, so pass ``backend=`` explicitly under jit)."""
  prev = _DEFAULT["value"]
  set_default_backend(backend)
  try:
    yield
  finally:
    _DEFAULT["value"] = prev


def _env_backend() -> str | None:
  """Validated ``REPRO_BACKEND`` value, or None when unset/empty.

  Validated at read time: an unknown value would otherwise surface much
  later as a confusing registry KeyError deep inside a traced call.
  """
  raw = os.environ.get(ENV_VAR)
  if not raw:
    return None
  if raw not in BACKENDS:
    raise ValueError(
        f"{ENV_VAR}={raw!r} is not a known backend; "
        f"expected one of {BACKENDS}")
  return raw


def resolve_backend(
    op: str,
    regularization: str,
    backend: str | None = None,
    *,
    shape: tuple[int, ...] | None = None,
    platform: str | None = None,
) -> str:
  """Resolve a possibly-None/"auto" backend request to a concrete backend.

  Deterministic given (request, environment, platform, shape): the same
  inputs always pick the same implementation, so a jit cache entry never
  flips backends between traces.
  """
  if backend:
    b, source = backend, "arg"
  else:
    env = _env_backend()
    if env:
      b, source = env, "env"
    else:
      b, source = _DEFAULT["value"], "default"
  if b != "auto":
    if (op, regularization, b) not in _REGISTRY:
      raise ValueError(
          f"no backend {b!r} registered for op={op!r}, "
          f"regularization={regularization!r}; have "
          f"{registered_backends(op, regularization)}")
    _metrics.counter_inc("dispatch_resolve", op=op,
                         regularization=regularization, backend=b,
                         source=source)
    return b
  platform = platform or jax.default_backend()
  n = shape[-1] if shape else 0
  rows = 1
  for d in (shape[:-1] if shape else ()):
    rows *= d
  if platform == "tpu":
    b, why = "pallas", "tpu"
  elif n <= AUTO_MINIMAX_MAX_N and rows * n * n <= AUTO_MINIMAX_MAX_ELEMS:
    b, why = "minimax", "small_n"
  else:
    b, why = "lax", "large_or_batched"
  _metrics.counter_inc("dispatch_resolve", op=op,
                       regularization=regularization, backend=b,
                       source="auto")
  _metrics.counter_inc("dispatch_auto_route", platform=platform,
                       backend=b, reason=why)
  return b


# Trace-key cache: (op, reg, backend, flat shape, dtype) tuples already seen
# by ``dispatch``.  A repeated key means jit served the call from its
# compile cache (or re-traced an identical signature); a new key is a fresh
# trace/compile.  Only mutated while metrics are enabled, and cleared with
# the registry, so disabled mode retains no state.
_SEEN_TRACE_KEYS: set[tuple] = set()
_metrics.on_reset(_SEEN_TRACE_KEYS.clear)


def dispatch(op: str, regularization: str, backend: str | None,
             *args: Array) -> Array:
  """Route a batched forward pass to the resolved backend.

  All ``args`` must share a common shape whose last axis is the problem
  dimension; leading batch axes are flattened to a single row axis before
  the backend call and restored afterwards, so backends only ever see
  (rows, n).

  The backend call runs under ``jax.named_scope`` (see
  ``repro.obs.tracing.scope_name``) so its primitives are attributable in
  profiler traces, and — when metrics are enabled — records per-backend
  call counts, flattened shape buckets, and trace-cache hit/miss counters.
  """
  shape = args[0].shape
  b = resolve_backend(op, regularization, backend, shape=shape)
  fn = _REGISTRY[(op, regularization, b)]
  n = shape[-1]
  flat = [a.reshape(-1, n) for a in args]
  if _metrics.enabled():
    rows = flat[0].shape[0] if n else 0
    _metrics.counter_inc("dispatch_calls", op=op,
                         regularization=regularization, backend=b)
    _metrics.counter_inc("dispatch_shape", op=op,
                         bucket=_metrics.shape_bucket(rows, n))
    key = (op, regularization, b, flat[0].shape,
           str(jnp.result_type(args[0])))
    if key in _SEEN_TRACE_KEYS:
      _metrics.counter_inc("dispatch_trace_cache_hit")
    else:
      _SEEN_TRACE_KEYS.add(key)
      _metrics.counter_inc("dispatch_trace_cache_miss")
  with _tracing.backend_scope(op, regularization, b):
    return fn(*flat).reshape(shape)


# ---------------------------------------------------------------------------
# Backend registration (isotonic optimization, paper §5).
# ---------------------------------------------------------------------------

from repro.kernels import pav as _pav  # noqa: E402
from repro.kernels import ref as _ref  # noqa: E402

register("isotonic", "l2", "lax")(_pav.pav_l2_lax)
register("isotonic", "kl", "lax")(_pav.pav_kl_lax)

register("isotonic", "l2", "pallas")(_pav.pav_l2)
register("isotonic", "kl", "pallas")(_pav.pav_kl)


@register("isotonic", "l2", "minimax")
def _pav_l2_minimax(y: Array) -> Array:
  # promote (not downcast): f64 stays f64 under x64, halves compute in f32
  yc = y.astype(jnp.promote_types(y.dtype, jnp.float32))
  return _ref.pav_l2_ref(yc).astype(y.dtype)


@register("isotonic", "kl", "minimax")
def _pav_kl_minimax(s: Array, w: Array) -> Array:
  dt = jnp.promote_types(s.dtype, jnp.float32)
  return _ref.pav_kl_ref(s.astype(dt), w.astype(dt)).astype(s.dtype)

"""Backend dispatch for the batched isotonic/projection stack.

Single choke point through which every soft-sort/rank pass routes: a
*forward* registry mapping ``(op, regularization, backend)`` ->
implementation, and a *backward* registry mapping
``(op, regularization, backward_backend)`` -> VJP implementation.  All
registered implementations share the same contract — they take f32-safe
arrays whose *last* axis is the problem dimension, flattened here to
``(rows, n)``, and return the same shape.

Forward backends
----------------
* ``"lax"``      reference ``lax.fori_loop`` stack machine, natively batched
                 (``repro.kernels.pav.pav_l2_lax`` / ``pav_kl_lax``);
                 O(n) work per row but O(n) *sequential depth*.
* ``"scan"``     divide-and-conquer PAV (``repro.kernels.pav_scan``):
                 log2(n) vectorized merge levels — O(n log n) work at
                 O(log n) depth, the paper's complexity claim realized on
                 depth-dominated hardware (CPU/GPU).
* ``"pallas"``   tiled TPU kernel (``repro.kernels.pav``); interpret mode
                 off-TPU, so it is usable (slowly) everywhere.
* ``"minimax"``  O(n^2) vectorized closed form (``repro.kernels.ref``) with
                 zero data-dependent control flow — the right trade for
                 small n and under SPMD.
* ``"auto"``     resolves deterministically from platform and shape at trace
                 time: TPU -> ``"pallas"``; otherwise ``"minimax"`` for
                 small problems (n <= 64 and rows * n^2 bounded) else
                 ``"scan"``.  An *unknown* shape (``shape=None``) resolves
                 to ``"scan"`` — never to the O(n^2) closed form.

Backward backends
-----------------
The exact O(n) segment-algebra VJP (paper Lemma 2) has two registered
formulations (``repro.kernels.segment_vjp``): ``"segscan"`` (default;
segmented prefix scans + block-end gathers, scatter-free) and
``"scatter"`` (the original ``segment_sum`` over globally-offset ids).
``resolve_backward`` follows the same precedence chain as the forward path
with its own ``REPRO_BACKWARD`` environment variable.

Selection precedence: explicit ``backend=`` argument > environment variable
(``REPRO_BACKEND`` / ``REPRO_BACKWARD``) > ``set_default_backend`` /
``use_backend`` process default (initially ``"auto"``).

Observability: every resolution and every dispatched call (forward and
backward) is recorded into ``repro.obs.metrics`` (counters keyed by
``(op, regularization, backend)``, shape buckets, auto-routing decisions,
and bounded trace-cache hit/miss/eviction counts), and every backend call
runs under a ``jax.named_scope`` so kernels are attributable in jaxprs /
HLO metadata / ``jax.profiler`` traces.  All of this happens at Python
trace time only, and is a no-op when metrics are disabled
(``REPRO_METRICS=0``).
"""

from __future__ import annotations

import contextlib
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

Array = jax.Array

ENV_VAR = "REPRO_BACKEND"
BWD_ENV_VAR = "REPRO_BACKWARD"
PROJECTION_ENV_VAR = "REPRO_PROJECTION"

BACKENDS = ("auto", "lax", "scan", "pallas", "minimax")
BWD_BACKENDS = ("auto", "segscan", "scatter")
PROJECTION_PATHS = ("auto", "fused", "composed")

# n at or below which the O(n^2) closed form beats the log-depth machines
# off-TPU (no control flow at all, trivially vectorized; memory is
# rows * n^2 floats).
AUTO_MINIMAX_MAX_N = 64

# Cap on rows * n^2 f32 elements for auto-selecting minimax (~64 MB): a
# large flattened batch at small n (the MoE-router regime) must fall back
# to the O(rows * n log n) scan machine instead of materializing rows
# (n, n) matrices.
AUTO_MINIMAX_MAX_ELEMS = 16_000_000

_REGISTRY: dict[tuple[str, str, str], Callable[..., Array]] = {}
_BWD_REGISTRY: dict[tuple[str, str, str], Callable[..., tuple]] = {}

_DEFAULT = {"value": "auto"}
_BWD_DEFAULT = {"value": "auto"}


def register(op: str, regularization: str, backend: str):
  """Decorator: register ``fn`` as the (op, regularization, backend) impl."""

  def deco(fn: Callable[..., Array]) -> Callable[..., Array]:
    _REGISTRY[(op, regularization, backend)] = fn
    return fn

  return deco


def register_backward(op: str, regularization: str, backend: str):
  """Decorator: register a VJP impl under (op, regularization, backend)."""

  def deco(fn: Callable[..., tuple]) -> Callable[..., tuple]:
    _BWD_REGISTRY[(op, regularization, backend)] = fn
    return fn

  return deco


def registered_backends(op: str, regularization: str) -> tuple[str, ...]:
  """Concrete (non-auto) backends registered for an (op, regularization)."""
  return tuple(b for (o, r, b) in _REGISTRY
               if o == op and r == regularization)


def registered_backward_backends(
    op: str, regularization: str) -> tuple[str, ...]:
  """Concrete backward backends registered for an (op, regularization)."""
  return tuple(b for (o, r, b) in _BWD_REGISTRY
               if o == op and r == regularization)


def get_default_backend() -> str:
  return _DEFAULT["value"]


def set_default_backend(backend: str) -> None:
  if backend not in BACKENDS:
    raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
  _DEFAULT["value"] = backend


@contextlib.contextmanager
def use_backend(backend: str):
  """Temporarily select the default backend (trace-time only: custom_vjp
  fwd rules are traced lazily, so pass ``backend=`` explicitly under jit)."""
  prev = _DEFAULT["value"]
  set_default_backend(backend)
  try:
    yield
  finally:
    _DEFAULT["value"] = prev


def get_default_backward() -> str:
  return _BWD_DEFAULT["value"]


def set_default_backward(backend: str) -> None:
  if backend not in BWD_BACKENDS:
    raise ValueError(
        f"backward backend must be one of {BWD_BACKENDS}, got {backend!r}")
  _BWD_DEFAULT["value"] = backend


@contextlib.contextmanager
def use_backward(backend: str):
  """Temporarily select the backward (VJP) formulation (trace-time only:
  like ``use_backend``, custom_vjp bwd rules are traced lazily under jit —
  eager/top-level ``jax.grad`` calls are the reliable use)."""
  prev = _BWD_DEFAULT["value"]
  set_default_backward(backend)
  try:
    yield
  finally:
    _BWD_DEFAULT["value"] = prev


def _env_choice(env_var: str, allowed: tuple[str, ...]) -> str | None:
  """Validated environment backend value, or None when unset/empty.

  Validated at read time: an unknown value would otherwise surface much
  later as a confusing registry KeyError deep inside a traced call.
  """
  raw = os.environ.get(env_var)
  if not raw:
    return None
  if raw not in allowed:
    raise ValueError(
        f"{env_var}={raw!r} is not a known backend; "
        f"expected one of {allowed}")
  return raw


def resolve_backend(
    op: str,
    regularization: str,
    backend: str | None = None,
    *,
    shape: tuple[int, ...] | None = None,
    platform: str | None = None,
) -> str:
  """Resolve a possibly-None/"auto" backend request to a concrete backend.

  Deterministic given (request, environment, platform, shape): the same
  inputs always pick the same implementation, so a jit cache entry never
  flips backends between traces.
  """
  if backend:
    b, source = backend, "arg"
  else:
    env = _env_choice(ENV_VAR, BACKENDS)
    if env:
      b, source = env, "env"
    else:
      b, source = _DEFAULT["value"], "default"
  if b != "auto":
    if (op, regularization, b) not in _REGISTRY:
      raise ValueError(
          f"no backend {b!r} registered for op={op!r}, "
          f"regularization={regularization!r}; have "
          f"{registered_backends(op, regularization)}")
    _metrics.counter_inc("dispatch_resolve", op=op,
                         regularization=regularization, backend=b,
                         source=source)
    return b
  platform = platform or jax.default_backend()
  if platform == "tpu":
    b, why = "pallas", "tpu"
  elif shape is None:
    # Unknown shape must NOT satisfy the small-n minimax test (an n=0
    # placeholder would silently pick the O(n^2) backend for arbitrarily
    # large problems); fall back to the shape-oblivious log-depth machine.
    b, why = "scan", "shapeless"
  else:
    n = shape[-1]
    rows = 1
    for d in shape[:-1]:
      rows *= d
    if n <= AUTO_MINIMAX_MAX_N and rows * n * n <= AUTO_MINIMAX_MAX_ELEMS:
      b, why = "minimax", "small_n"
    else:
      b, why = "scan", "large_or_batched"
  _metrics.counter_inc("dispatch_resolve", op=op,
                       regularization=regularization, backend=b,
                       source="auto")
  _metrics.counter_inc("dispatch_auto_route", platform=platform,
                       backend=b, reason=why)
  return b


def resolve_backward(
    op: str,
    regularization: str,
    backend: str | None = None,
) -> str:
  """Resolve a backward (VJP) backend request: arg > env > default."""
  if backend:
    b, source = backend, "arg"
  else:
    env = _env_choice(BWD_ENV_VAR, BWD_BACKENDS)
    if env:
      b, source = env, "env"
    else:
      b, source = _BWD_DEFAULT["value"], "default"
  if b == "auto":
    b, source = "segscan", source if source != "default" else "auto"
  if (op, regularization, b) not in _BWD_REGISTRY:
    raise ValueError(
        f"no backward backend {b!r} registered for op={op!r}, "
        f"regularization={regularization!r}; have "
        f"{registered_backward_backends(op, regularization)}")
  _metrics.counter_inc("dispatch_bwd_resolve", op=op,
                       regularization=regularization, backend=b,
                       source=source)
  return b


# Trace-key cache: (op, reg, backend, flat shape, dtype) tuples already seen
# by ``dispatch``.  A repeated key means jit served the call from its
# compile cache (or re-traced an identical signature); a new key is a fresh
# trace/compile.  Only mutated while metrics are enabled, and cleared with
# the registry, so disabled mode retains no state.  Bounded: a long-running
# server seeing unboundedly many distinct shapes (launch/serve.py ragged
# batches) must not leak one tuple per shape forever, so insertion order is
# tracked and the oldest key is evicted at the cap (the eviction count is
# itself a metric — a hot eviction counter means the cache is thrashing and
# hit/miss ratios undercount true jit cache hits).
TRACE_KEY_CAP = 4096
_SEEN_TRACE_KEYS: dict[tuple, None] = {}
_metrics.on_reset(_SEEN_TRACE_KEYS.clear)


def _trace_cache_note(key: tuple) -> None:
  """Record hit/miss for a dispatch trace key, evicting at the cap."""
  if key in _SEEN_TRACE_KEYS:
    _metrics.counter_inc("dispatch_trace_cache_hit")
    return
  while len(_SEEN_TRACE_KEYS) >= TRACE_KEY_CAP:
    _SEEN_TRACE_KEYS.pop(next(iter(_SEEN_TRACE_KEYS)))
    _metrics.counter_inc("dispatch_trace_cache_evict")
  _SEEN_TRACE_KEYS[key] = None
  _metrics.counter_inc("dispatch_trace_cache_miss")


def resolve_projection(path: str | None = None) -> str:
  """Resolve a projection-path request: arg > env > default ("fused").

  The projection registry (``("projection", reg, path)`` keys, populated on
  ``repro.core.projection`` import) holds whole-pipeline implementations:
  ``"fused"`` — single custom VJP around sort + isotonic solve + gather,
  packed integer sorts, gather-only backward; ``"composed"`` — the
  reference chain of four differentiable primitives, kept reachable (env
  ``REPRO_PROJECTION=composed``) for differential testing.
  """
  if path:
    p, source = path, "arg"
  else:
    env = _env_choice(PROJECTION_ENV_VAR, PROJECTION_PATHS)
    if env:
      p, source = env, "env"
    else:
      p, source = "auto", "default"
  if p == "auto":
    p = "fused"
  if p not in PROJECTION_PATHS:
    raise ValueError(
        f"projection path must be one of {PROJECTION_PATHS}, got {p!r}")
  _metrics.counter_inc("projection_resolve", path=p, source=source)
  return p


def dispatch_projection(z: Array, w: Array, regularization: str,
                        impl: str | None, path: str | None = None,
                        **kwargs) -> Array:
  """Route a permutahedron projection to the fused or composed pipeline.

  Unlike ``dispatch``, implementations here own their batching (the fused
  path needs the unflattened unbatched-``w`` shape to share one weight
  sort across the batch), so ``z``/``w`` pass through unflattened;
  ``kwargs`` carry the static sortedness flags and optional precomputed
  permutations.  Runs under a ``repro_projection_<reg>_<path>`` named
  scope; fused calls are counted as ``projection_fused_calls``.
  """
  p = resolve_projection(path)
  fn = _REGISTRY.get(("projection", regularization, p))
  if fn is None:
    raise ValueError(
        f"no projection path {p!r} registered for "
        f"regularization={regularization!r} (import repro.core.projection); "
        f"have {registered_backends('projection', regularization)}")
  if p == "fused":
    _metrics.counter_inc("projection_fused_calls",
                         regularization=regularization)
  _metrics.counter_inc("dispatch_calls", op="projection",
                       regularization=regularization, backend=p)
  with _tracing.backend_scope("projection", regularization, p):
    return fn(z, w, impl, **kwargs)


def dispatch(op: str, regularization: str, backend: str | None,
             *args: Array) -> Array:
  """Route a batched forward pass to the resolved backend.

  All ``args`` must share a common shape whose last axis is the problem
  dimension; leading batch axes are flattened to a single row axis before
  the backend call and restored afterwards, so backends only ever see
  (rows, n).

  The backend call runs under ``jax.named_scope`` (see
  ``repro.obs.tracing.scope_name``) so its primitives are attributable in
  profiler traces, and — when metrics are enabled — records per-backend
  call counts, flattened shape buckets, and trace-cache hit/miss counters.
  """
  shape = args[0].shape
  b = resolve_backend(op, regularization, backend, shape=shape)
  fn = _REGISTRY[(op, regularization, b)]
  n = shape[-1]
  flat = [a.reshape(-1, n) for a in args]
  if _metrics.enabled():
    rows = flat[0].shape[0] if n else 0
    _metrics.counter_inc("dispatch_calls", op=op,
                         regularization=regularization, backend=b)
    _metrics.counter_inc("dispatch_shape", op=op,
                         bucket=_metrics.shape_bucket(rows, n))
    _trace_cache_note((op, regularization, b, flat[0].shape,
                       str(jnp.result_type(args[0]))))
  with _tracing.backend_scope(op, regularization, b):
    return fn(*flat).reshape(shape)


def dispatch_backward(op: str, regularization: str, backend: str | None,
                      *args: Array):
  """Route a batched VJP to the resolved backward backend.

  Same flattening contract as ``dispatch``; the impl may return a single
  gradient array or a tuple of gradient arrays (each is restored to the
  original batch shape).  Runs under a ``repro_<op>_bwd_<reg>_<backend>``
  named scope and records ``dispatch_bwd_calls`` counters.
  """
  shape = args[0].shape
  b = resolve_backward(op, regularization, backend)
  fn = _BWD_REGISTRY[(op, regularization, b)]
  n = shape[-1]
  flat = [a.reshape(-1, n) for a in args]
  _metrics.counter_inc("dispatch_bwd_calls", op=op,
                       regularization=regularization, backend=b)
  with _tracing.backend_scope(f"{op}_bwd", regularization, b):
    out = fn(*flat)
  if isinstance(out, tuple):
    return tuple(o.reshape(shape) for o in out)
  return out.reshape(shape)


# ---------------------------------------------------------------------------
# Backend registration (isotonic optimization, paper §5).
# ---------------------------------------------------------------------------

from repro.kernels import pav as _pav  # noqa: E402
from repro.kernels import pav_scan as _pav_scan  # noqa: E402
from repro.kernels import ref as _ref  # noqa: E402
from repro.kernels import segment_vjp as _svjp  # noqa: E402

register("isotonic", "l2", "lax")(_pav.pav_l2_lax)
register("isotonic", "kl", "lax")(_pav.pav_kl_lax)

register("isotonic", "l2", "scan")(_pav_scan.pav_l2_scan)
register("isotonic", "kl", "scan")(_pav_scan.pav_kl_scan)

register("isotonic", "l2", "pallas")(_pav.pav_l2)
register("isotonic", "kl", "pallas")(_pav.pav_kl)


@register("isotonic", "l2", "minimax")
def _pav_l2_minimax(y: Array) -> Array:
  # promote (not downcast): f64 stays f64 under x64, halves compute in f32
  yc = y.astype(jnp.promote_types(y.dtype, jnp.float32))
  return _ref.pav_l2_ref(yc).astype(y.dtype)


@register("isotonic", "kl", "minimax")
def _pav_kl_minimax(s: Array, w: Array) -> Array:
  dt = jnp.promote_types(s.dtype, jnp.float32)
  return _ref.pav_kl_ref(s.astype(dt), w.astype(dt)).astype(s.dtype)


register_backward("isotonic", "l2", "segscan")(_svjp.isotonic_l2_bwd_segscan)
register_backward("isotonic", "l2", "scatter")(_svjp.isotonic_l2_bwd_scatter)
register_backward("isotonic", "kl", "segscan")(_svjp.isotonic_kl_bwd_segscan)
register_backward("isotonic", "kl", "scatter")(_svjp.isotonic_kl_bwd_scatter)

# Fused-projection backward table: same Lemma 2 segment algebra, consuming
# the block structure precomputed by the fused forward (residuals) instead
# of re-deriving it from the solver output.  Forward projection paths
# ("fused" / "composed") register themselves on ``repro.core.projection``
# import — kernels must not import core.
register_backward("projection", "l2",
                  "segscan")(_svjp.projection_l2_bwd_segscan)
register_backward("projection", "l2",
                  "scatter")(_svjp.projection_l2_bwd_scatter)
register_backward("projection", "kl",
                  "segscan")(_svjp.projection_kl_bwd_segscan)
register_backward("projection", "kl",
                  "scatter")(_svjp.projection_kl_bwd_scatter)

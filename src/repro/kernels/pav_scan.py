"""Log-depth divide-and-conquer PAV: the ``"scan"`` isotonic backend.

The ``"lax"`` stack machine and its Pallas port are exact but *sequential*:
``lax.fori_loop`` over all n positions with a data-dependent inner
``while_loop`` — O(n) loop depth, which is what dominates wall-clock on
CPU/GPU even though the work is linear.  This module evaluates the same
Pool-Adjacent-Violators fixed point by divide and conquer instead:

* level ``l`` starts from solved segments of size ``m = 2**l`` and merges
  adjacent pairs into solved segments of size ``2m``;
* concatenating two isotonic (non-increasing) solutions is non-increasing
  everywhere except possibly at the pair boundary, and the merged optimum
  differs from the concatenation by exactly ONE pooled block spanning that
  boundary (the classical PAV merge lemma: the optimal partition of the
  union coarsens both sub-partitions, and away from the boundary the block
  values are already strictly ordered);
* the boundary pool is grown by a vectorized masked absorption loop over
  *all* rows and *all* segment pairs of the level at once — each step is a
  handful of gathers/selects on ``(rows, pairs)`` arrays, and a pair that
  has reached its fixed point (previous block value > pool value > next
  block value) stops participating.

The merge-level loop runs over ``log2(n)`` levels with per-level shapes
(``pairs = n / 2m`` halves every level, so the absorption loops cost a
*geometric* series, not ``levels * n/2``); each level is one vectorized
merge sweep, giving the compiled program O(log n) sequential structure and
O(n log n) total work — versus O(n) sequential depth for the stack machine
and O(n^2) work for the minimax closed form.  Both regularizations share
the machinery through a small aggregate algebra:

* L2 (Eq. 7): registers ``(sum, count)``, merged by addition, block value
  ``sum / count`` — block means via running prefix sums;
* KL (Eq. 8): registers ``(LSE(s), LSE(w))``, merged by ``logaddexp``,
  block value ``LSE(s) - LSE(w)`` — exactly as stable as the reference
  because interval LSEs are only ever *combined*, never differenced.

Rows are padded to the next power of two with per-row sentinel blocks whose
value is strictly below any achievable block value (for L2 the row minimum;
for KL ``min(s) - max(w) - log(n) - 1`` via the mediant bound), so padding
never pools with real data and is sliced off afterwards.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_INT = jnp.int32


def _next_pow2(n: int) -> int:
  return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _gather(arr: Array, idx: Array) -> Array:
  """arr: (B, N), idx: (B, P) or (P,) -> (B, P) gather along the last axis."""
  if idx.ndim == 1:
    idx = jnp.broadcast_to(idx[None, :], (arr.shape[0], idx.shape[0]))
  return jnp.take_along_axis(arr, idx, axis=1)


def _merge_level(start, end, regs, lvl, merge, block_value):
  """Merge adjacent solved segments of size 2**lvl, vectorized over rows
  and over all pairs of the level.  Shapes: start/end/regs are (B, N);
  all pair-indexed intermediates are (B, N >> (lvl+1))."""
  n = start.shape[1]
  m = 1 << lvl
  npairs = n >> (lvl + 1)
  pairs = jnp.arange(npairs, dtype=_INT)
  seg_lo = 2 * m * pairs          # first position of the pair
  seg_hi = seg_lo + 2 * m - 1     # last position of the pair
  bnd = seg_lo + m                # first position of the right segment

  # Boundary blocks: bnd is a block start by construction; bnd-1's block
  # starts at start[bnd-1].
  l_start = _gather(start, bnd - 1)
  l_regs = tuple(_gather(r, l_start) for r in regs)
  r_regs = tuple(_gather(r, bnd) for r in regs)
  viol = block_value(l_regs) < block_value(r_regs)

  # Initial pool = left boundary block + right boundary block.
  bnd_b = jnp.broadcast_to(bnd, l_start.shape)
  pl = jnp.where(viol, l_start, bnd_b)
  pr = jnp.where(viol, _gather(end, bnd), bnd_b)
  pregs = tuple(jnp.where(viol, m_, r_)
                for m_, r_ in zip(merge(l_regs, r_regs), r_regs))

  def w_cond(state):
    return jnp.any(state[3])

  def w_body(state):
    pl, pr, pregs, live = state
    gamma = block_value(pregs)
    # Left neighbor block of the pool (if the pool is not at seg_lo).
    has_l = live & (pl > seg_lo)
    nb_l_start = _gather(start, jnp.maximum(pl - 1, 0))
    nb_l_regs = tuple(_gather(r, nb_l_start) for r in regs)
    absorb_l = has_l & (block_value(nb_l_regs) < gamma)
    # Right neighbor block (starts at pr + 1 when inside the pair).
    has_r = live & (pr < seg_hi)
    nb_r_idx = jnp.minimum(pr + 1, n - 1)
    nb_r_regs = tuple(_gather(r, nb_r_idx) for r in regs)
    nb_r_end = _gather(end, nb_r_idx)
    absorb_r = has_r & (gamma < block_value(nb_r_regs))
    # Both absorptions are decided against the same pool value: absorbing
    # the left block only lowers gamma (keeping the right violation valid)
    # and vice versa, so simultaneous absorption preserves exactness.
    pregs = tuple(jnp.where(absorb_l, m_, p_)
                  for m_, p_ in zip(merge(pregs, nb_l_regs), pregs))
    pl = jnp.where(absorb_l, nb_l_start, pl)
    pregs = tuple(jnp.where(absorb_r, m_, p_)
                  for m_, p_ in zip(merge(pregs, nb_r_regs), pregs))
    pr = jnp.where(absorb_r, nb_r_end, pr)
    return pl, pr, pregs, absorb_l | absorb_r

  pl, pr, pregs, _ = lax.while_loop(w_cond, w_body, (pl, pr, pregs, viol))

  # Write the pools back into the per-position block structure.
  iota = jnp.arange(n, dtype=_INT)
  pair_of = jnp.right_shift(iota, lvl + 1)        # (N,) position -> pair
  ppl = jnp.take(pl, pair_of, axis=1)
  ppr = jnp.take(pr, pair_of, axis=1)
  pooled = jnp.take(viol, pair_of, axis=1)
  in_pool = pooled & (ppl <= iota) & (iota <= ppr)
  start = jnp.where(in_pool, ppl, start)
  end = jnp.where(in_pool, ppr, end)
  regs = tuple(
      jnp.where(in_pool & (iota == ppl), jnp.take(p, pair_of, axis=1), r)
      for p, r in zip(pregs, regs))
  return start, end, regs


def _dac_pav(
    regs0: tuple[Array, ...],
    merge: Callable[[tuple, tuple], tuple],
    block_value: Callable[[tuple], Array],
) -> Array:
  """Run the divide-and-conquer PAV on per-position registers.

  ``regs0``: tuple of (B, N) arrays, N a power of two — the singleton-block
  registers of every position.  Returns the (B, N) fitted values.
  """
  b_rows, n = regs0[0].shape
  iota = jnp.arange(n, dtype=_INT)
  start = jnp.broadcast_to(iota, (b_rows, n))
  end = start
  regs = regs0
  for lvl in range(n.bit_length() - 1):
    start, end, regs = _merge_level(start, end, regs, lvl, merge, block_value)
  return block_value(tuple(_gather(r, start) for r in regs))


def _pad_cols(x: Array, n_pad: int, fill: Array) -> Array:
  """Append ``n_pad`` columns of per-row ``fill`` (shape (B, 1))."""
  if n_pad == 0:
    return x
  return jnp.concatenate(
      [x, jnp.broadcast_to(fill, (x.shape[0], n_pad))], axis=1)


@jax.jit
def pav_l2_scan(y: Array) -> Array:
  """Batched isotonic regression (non-increasing) on (B, n): D&C PAV."""
  dt = jnp.promote_types(y.dtype, jnp.float32)
  yc = y.astype(dt)
  b, n = yc.shape
  if n <= 1 or b == 0:
    return yc.astype(y.dtype)
  big_n = _next_pow2(n)
  # Sentinel: the row minimum can never strictly violate against any real
  # block (block means are >= the row minimum; comparisons are strict).
  pad = jnp.min(yc, axis=1, keepdims=True)
  yp = _pad_cols(yc, big_n - n, pad)
  regs0 = (yp, jnp.ones_like(yp))
  out = _dac_pav(
      regs0,
      merge=lambda a, c: (a[0] + c[0], a[1] + c[1]),
      block_value=lambda r: r[0] / jnp.maximum(r[1], 1e-30),
  )
  return out[:, :n].astype(y.dtype)


@jax.jit
def pav_kl_scan(s: Array, w: Array) -> Array:
  """Batched entropic isotonic optimization on (B, n) x (B, n): D&C PAV."""
  dt = jnp.promote_types(s.dtype, jnp.float32)
  sc, wc = s.astype(dt), w.astype(dt)
  b, n = sc.shape
  if n <= 1 or b == 0:
    # Singleton blocks: gamma_E({i}) = s_i - w_i (Eq. 8); empty passthrough.
    return (sc - wc).astype(s.dtype)
  big_n = _next_pow2(n)
  # Sentinel block value min(s) - max(w) - log(n) - 1 is strictly below any
  # real block value (LSE(s_B) >= min(s), LSE(w_B) <= max(w) + log n) and,
  # by the mediant inequality, below any pool of real blocks too.
  s_pad = jnp.min(sc, axis=1, keepdims=True)
  w_pad = jnp.max(wc, axis=1, keepdims=True) + jnp.log(jnp.asarray(n, dt)) + 1
  sp = _pad_cols(sc, big_n - n, s_pad)
  wp = _pad_cols(wc, big_n - n, w_pad)
  out = _dac_pav(
      (sp, wp),
      merge=lambda a, c: (jnp.logaddexp(a[0], c[0]),
                          jnp.logaddexp(a[1], c[1])),
      block_value=lambda r: r[0] - r[1],
  )
  return out[:, :n].astype(s.dtype)

"""Backward-pass backends for the isotonic custom VJPs (paper Lemma 2).

The Jacobian of an isotonic solve is block-diagonal with rank-1 blocks
recovered from runs of equal values in the forward output, so every VJP is
a composition of three within-block primitives over a (rows, n) batch:
sum-broadcast, mean-broadcast, and softmax.  This module provides two
interchangeable formulations of those primitives, registered in the
backward table of ``repro.kernels.dispatch``:

* ``"scatter"`` — the original formulation: per-row block ids are offset
  into one global id space and reduced with ``jax.ops.segment_sum``, which
  lowers to flat scatter-adds.  Kept as the reference backward backend.
* ``"segscan"`` — scatter-free: blocks are *contiguous runs* by
  construction (the forward output is sorted within a row), so each
  within-block reduction is a segmented prefix scan (``associative_scan``
  carrying a reset flag at block starts) followed by a gather of the
  block-end position.  O(n log n) work at O(log n) depth, no
  data-dependent scatter — the default since it vectorizes cleanly on
  every platform.

Both formulations are exact and agree to float roundoff; the dispatch
layer's backward table makes them swappable per call for equivalence tests
and perf sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_INT = jnp.int32


# ---------------------------------------------------------------------------
# Block-structure recovery (shared by both formulations).
# ---------------------------------------------------------------------------


def block_starts(v: Array) -> Array:
  """Boolean (B, n) marking the first position of each run of equal values."""
  return jnp.concatenate(
      [jnp.ones_like(v[:, :1], bool), v[:, 1:] != v[:, :-1]], axis=-1)


def block_ids(v: Array) -> Array:
  """Per-row segment ids from runs of equal values, v: (B, n) -> (B, n)."""
  return jnp.cumsum(block_starts(v).astype(_INT), axis=-1) - 1


def start_end_indices(starts: Array) -> tuple[Array, Array]:
  """Per-position block start/end indices from the start mask; (B, n) each."""
  b, n = starts.shape
  iota = jnp.broadcast_to(jnp.arange(n, dtype=_INT), (b, n))
  start_idx = lax.cummax(jnp.where(starts, iota, 0), axis=1)
  ends = jnp.concatenate(
      [starts[:, 1:], jnp.ones_like(starts[:, :1])], axis=-1)
  end_idx = jnp.flip(
      lax.cummin(jnp.flip(jnp.where(ends, iota, n - 1), axis=-1), axis=1),
      axis=-1)
  return start_idx, end_idx


# ---------------------------------------------------------------------------
# "segscan" primitives: segmented prefix scans + block-end gathers.
# ---------------------------------------------------------------------------


def _seg_scan(x: Array, starts: Array, combine) -> Array:
  """Inclusive segmented scan along the last axis, resetting at starts."""

  def op(a, b):
    va, fa = a
    vb, fb = b
    return jnp.where(fb, vb, combine(va, vb)), fa | fb

  out, _ = lax.associative_scan(op, (x, starts), axis=-1)
  return out


def _seg_total(x: Array, starts: Array, end_idx: Array, combine) -> Array:
  """Within-block reduction broadcast to every position of the block."""
  return jnp.take_along_axis(_seg_scan(x, starts, combine), end_idx, axis=-1)


def seg_sum_bcast(g: Array, starts: Array, end_idx: Array) -> Array:
  return _seg_total(g, starts, end_idx, jnp.add)


def seg_mean_bcast(g: Array, starts: Array, start_idx: Array,
                   end_idx: Array) -> Array:
  cnt = (end_idx - start_idx + 1).astype(g.dtype)
  return seg_sum_bcast(g, starts, end_idx) / cnt


def seg_softmax(x: Array, starts: Array, end_idx: Array) -> Array:
  """Softmax within each contiguous block (max-shifted, exact, stable)."""
  m = _seg_total(x, starts, end_idx, jnp.maximum)
  ex = jnp.exp(x - m)
  return ex / _seg_total(ex, starts, end_idx, jnp.add)


# ---------------------------------------------------------------------------
# "scatter" primitives: globally-offset segment ids + segment_sum.
# ---------------------------------------------------------------------------


def _flat_ids(bid: Array) -> Array:
  """Offset per-row block ids into one global id space (rows never mix)."""
  b, n = bid.shape
  return (bid + jnp.arange(b, dtype=_INT)[:, None] * n).reshape(-1)


def scatter_sum_bcast(g: Array, bid: Array) -> Array:
  """Within-block sum broadcast back to positions; g, bid: (B, n)."""
  b, n = g.shape
  gid = _flat_ids(bid)
  s = jax.ops.segment_sum(g.reshape(-1), gid, num_segments=b * n,
                          indices_are_sorted=True)
  return s[gid].reshape(b, n)


def scatter_mean_bcast(g: Array, bid: Array) -> Array:
  b, n = g.shape
  gid = _flat_ids(bid)
  gsum = jax.ops.segment_sum(g.reshape(-1), gid, num_segments=b * n,
                             indices_are_sorted=True)
  cnt = jax.ops.segment_sum(jnp.ones((b * n,), g.dtype), gid,
                            num_segments=b * n, indices_are_sorted=True)
  return (gsum / jnp.maximum(cnt, 1))[gid].reshape(b, n)


def scatter_softmax(x: Array, bid: Array) -> Array:
  """softmax within each block (exact, stable); x, bid: (B, n)."""
  b, n = x.shape
  gid = _flat_ids(bid)
  smax = jax.ops.segment_max(x.reshape(-1), gid, num_segments=b * n,
                             indices_are_sorted=True)
  ex = jnp.exp(x.reshape(-1) - smax[gid])
  denom = jax.ops.segment_sum(ex, gid, num_segments=b * n,
                              indices_are_sorted=True)
  return (ex / denom[gid]).reshape(b, n)


# ---------------------------------------------------------------------------
# Registered backward passes.  Contract: flattened (rows, n) arrays in,
# gradient arrays of the same shape out (dispatch restores batch shapes).
# ---------------------------------------------------------------------------


def isotonic_l2_bwd_segscan(v: Array, g: Array) -> Array:
  """Lemma 2 (Q): dv/dy has blocks 11^T/|B| -> within-block mean of g."""
  starts = block_starts(v)
  start_idx, end_idx = start_end_indices(starts)
  return seg_mean_bcast(g, starts, start_idx, end_idx)


def isotonic_l2_bwd_scatter(v: Array, g: Array) -> Array:
  return scatter_mean_bcast(g, block_ids(v))


def isotonic_kl_bwd_segscan(s: Array, w: Array, v: Array,
                            g: Array) -> tuple[Array, Array]:
  """Lemma 2 (E): B_j = 1 (x) softmax(s_B); transpose-multiply gives
  grad_s = softmax(s_B) * sum(g_B) and grad_w = -softmax(w_B) * sum(g_B)."""
  starts = block_starts(v)
  _, end_idx = start_end_indices(starts)
  gs = seg_sum_bcast(g, starts, end_idx)
  grad_s = seg_softmax(s, starts, end_idx) * gs
  grad_w = -seg_softmax(w, starts, end_idx) * gs
  return grad_s, grad_w


def isotonic_kl_bwd_scatter(s: Array, w: Array, v: Array,
                            g: Array) -> tuple[Array, Array]:
  bid = block_ids(v)
  gs = scatter_sum_bcast(g, bid)
  grad_s = scatter_softmax(s, bid) * gs
  grad_w = -scatter_softmax(w, bid) * gs
  return grad_s, grad_w


# ---------------------------------------------------------------------------
# Projection backward passes (fused whole-pipeline VJP).
#
# Same Lemma 2 algebra as the isotonic VJPs above, but consuming the block
# structure (start mask + per-position start/end indices) *precomputed by
# the fused projection forward* and saved as custom-VJP residuals, instead
# of re-deriving it from the solver output on every backward call.  The
# ``starts`` mask is carried as the solver dtype (dispatch reshapes every
# residual through the same (rows, n) contract) and re-read as boolean
# here.
# ---------------------------------------------------------------------------


def _starts_bool(starts: Array) -> Array:
  return starts.astype(bool)


def projection_l2_bwd_segscan(g: Array, starts: Array, start_idx: Array,
                              end_idx: Array) -> Array:
  """Lemma 2 (Q) with precomputed blocks: within-block mean of g."""
  return seg_mean_bcast(g, _starts_bool(starts), start_idx.astype(_INT),
                        end_idx.astype(_INT))


def projection_l2_bwd_scatter(g: Array, starts: Array, start_idx: Array,
                              end_idx: Array) -> Array:
  del start_idx, end_idx
  bid = jnp.cumsum(_starts_bool(starts).astype(_INT), axis=-1) - 1
  return scatter_mean_bcast(g, bid)


def projection_kl_bwd_segscan(s: Array, w: Array, g: Array, starts: Array,
                              start_idx: Array,
                              end_idx: Array) -> tuple[Array, Array]:
  """Lemma 2 (E) with precomputed blocks: softmax-weighted block sums."""
  del start_idx
  sb = _starts_bool(starts)
  ei = end_idx.astype(_INT)
  gs = seg_sum_bcast(g, sb, ei)
  return seg_softmax(s, sb, ei) * gs, -seg_softmax(w, sb, ei) * gs


def projection_kl_bwd_scatter(s: Array, w: Array, g: Array, starts: Array,
                              start_idx: Array,
                              end_idx: Array) -> tuple[Array, Array]:
  del start_idx, end_idx
  bid = jnp.cumsum(_starts_bool(starts).astype(_INT), axis=-1) - 1
  gs = scatter_sum_bcast(g, bid)
  return scatter_softmax(s, bid) * gs, -scatter_softmax(w, bid) * gs

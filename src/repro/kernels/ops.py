"""Jitted public wrappers for the Pallas kernels.

``repro.core.isotonic`` routes its batched forward passes here when
``set_default_impl('pallas')`` is active; the custom VJPs in core are shared
(the backward is implementation-independent segment algebra).
"""

from __future__ import annotations

import jax

from repro.kernels.pav import pav_kl, pav_l2
from repro.kernels.soft_topk import soft_topk_gates

__all__ = ["pav_l2", "pav_kl", "soft_topk_gates"]

"""Jitted public wrappers for the Pallas kernels.

``repro.kernels.dispatch`` routes the batched isotonic forward passes here
when the ``"pallas"`` backend is selected (default on TPU under ``"auto"``);
the custom VJPs in core are shared (the backward is backend-independent
segment algebra).  ``pav_l2_lax`` / ``pav_kl_lax`` are the same stack
machine run as plain lax code — the ``"lax"`` reference backend.
"""

from __future__ import annotations

from repro.kernels.pav import pav_kl, pav_kl_lax, pav_l2, pav_l2_lax
from repro.kernels.soft_topk import soft_topk_gates

__all__ = ["pav_l2", "pav_kl", "pav_l2_lax", "pav_kl_lax",
           "soft_topk_gates"]

"""Pallas TPU kernel: fused causal flash attention (forward).

The §Roofline analysis found every train cell memory-bound because the
XLA-level chunked attention round-trips (cq x ckv) score blocks through
HBM between fusions (EXPERIMENTS.md §Perf, gemma3/xlstm conclusions).
This kernel is the identified fix: one ``pallas_call`` per (batch, kv-head,
q-block) grid cell keeps Q/K/V blocks and the running (m, l, acc) state in
VMEM — HBM traffic collapses to reading Q, K, V once and writing O once.

Grid: (B, Hkv, Sq / BLOCK_Q); the kernel loops over kv blocks with
``lax.fori_loop`` entirely in registers/VMEM.  GQA handled by loading all
G query groups of a kv head per cell.  Validated in interpret mode against
``repro.models.layers.flash_attention`` (the pure-JAX reference).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array

_NEG_INF = -1e30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *,
                  block_q: int, block_kv: int, skv: int, scale: float,
                  causal: bool):
  """One (batch, kv-head, q-block) cell.

  q_ref: (G, block_q, D); k_ref/v_ref: (Skv, D); o_ref: (G, block_q, Dv).
  """
  qi = pl.program_id(2)
  q = q_ref[...].astype(jnp.float32) * scale          # (G, bq, D)
  g, bq, d = q.shape
  dv = o_ref.shape[-1]
  q_pos = qi * block_q + jnp.arange(block_q)

  nkv = skv // block_kv
  if causal:
    # kv blocks beyond this q block never contribute: skip them.
    last = jnp.minimum(
        (qi * block_q + block_q + block_kv - 1) // block_kv, nkv)
  else:
    last = nkv

  def body(j, carry):
    m, l, acc = carry
    k_blk = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
    v_blk = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
    s = jnp.einsum("gqd,kd->gqk", q, k_blk)           # (G, bq, bkv)
    if causal:
      kv_pos = j * block_kv + jnp.arange(block_kv)
      mask = kv_pos[None, :] <= q_pos[:, None]
      s = jnp.where(mask[None], s, _NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum("gqk,kv->gqv", p, v_blk)
    return m_new, l_new, acc_new

  m0 = jnp.full((g, bq), _NEG_INF, jnp.float32)
  l0 = jnp.zeros((g, bq), jnp.float32)
  acc0 = jnp.zeros((g, bq, dv), jnp.float32)
  m, l, acc = lax.fori_loop(0, last, body, (m0, l0, acc0))
  o_ref[...] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"))
def flash_attention_tpu(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool | None = None,
) -> Array:
  """Fused attention. q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D|Dv)."""
  if interpret is None:
    interpret = jax.default_backend() != "tpu"
  b, sq, h, d = q.shape
  _, skv, hkv, dv = v.shape
  g = h // hkv
  scale = 1.0 / math.sqrt(d)
  block_q = min(block_q, sq)
  block_kv = min(block_kv, skv)
  while sq % block_q:
    block_q -= 1
  while skv % block_kv:
    block_kv -= 1

  # (B, Hkv, G, S, D) layout: one grid cell sees all G groups of a kv head.
  qt = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
  kt = k.transpose(0, 2, 1, 3)                       # (B, Hkv, Skv, D)
  vt = v.transpose(0, 2, 1, 3)                       # (B, Hkv, Skv, Dv)

  grid = (b, hkv, sq // block_q)
  out = pl.pallas_call(
      functools.partial(
          _flash_kernel, block_q=block_q, block_kv=block_kv, skv=skv,
          scale=scale, causal=causal),
      out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq, dv), q.dtype),
      grid=grid,
      in_specs=[
          pl.BlockSpec((None, None, g, block_q, d),
                       lambda bi, hi, qi: (bi, hi, 0, qi, 0)),
          pl.BlockSpec((None, None, skv, d),
                       lambda bi, hi, qi: (bi, hi, 0, 0)),
          pl.BlockSpec((None, None, skv, dv),
                       lambda bi, hi, qi: (bi, hi, 0, 0)),
      ],
      out_specs=pl.BlockSpec((None, None, g, block_q, dv),
                             lambda bi, hi, qi: (bi, hi, 0, qi, 0)),
      interpret=interpret,
  )(qt, kt, vt)
  return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)

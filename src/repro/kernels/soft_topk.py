"""Pallas TPU kernel: fused differentiable top-k router gate.

Computes, per row of logits, the projection of ``logits/eps`` onto the
k-subset permutahedron P((1,..,1,0,..,0)) — the paper's soft top-k — as one
fused kernel with **zero data-dependent control flow**:

  1. bitonic sort network over lanes (n_experts <= 128, padded to a power of
     two; fixed comparator sequence — the TPU analogue of warp-shuffle
     sorting networks on GPU);
  2. isotonic regression via the minimax closed form
     v_i = min_{j<=i} max_{k>=i} mean(y[j..k]) evaluated as an O(E^2)
     interval-mean matrix: for router-sized E this trades FLOPs for full
     vectorization — the right call on a machine whose scalar unit is ~100x
     slower than its VPU (DESIGN.md §3);
  3. un-permutation by a second bitonic pass keyed on the original indices.

Rows (tokens) ride the sublane dimension; the grid tiles tokens.  This is
the MoE-router hot path for the deepseek-v2-lite (64e top-6) and grok-1
(8e top-2) architectures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_TOKEN_TILE = 128
_NEG = -1e30  # python scalar: jnp scalars would be captured consts in pallas


def _bitonic(keys: Array, payload: Array, descending: bool = True):
  """Bitonic sort along the last axis (power-of-two length) with payload.

  Fixed comparator network: log2(n)*(log2(n)+1)/2 compare-exchange rounds of
  pure vector selects.  Ties broken by payload (original index) so the sort
  is deterministic.
  """
  n = keys.shape[-1]
  assert (n & (n - 1)) == 0, "bitonic length must be a power of two"
  lane = jnp.arange(n, dtype=jnp.int32)
  size = 2
  while size <= n:
    stride = size // 2
    while stride >= 1:
      partner = lane ^ stride
      k_p = jnp.take(keys, partner, axis=-1)
      p_p = jnp.take(payload, partner, axis=-1)
      is_lower = (lane & stride) == 0
      block_desc = ((lane & size) == 0) == descending
      want_max = jnp.logical_not(jnp.logical_xor(is_lower, block_desc))
      partner_bigger = (k_p > keys) | ((k_p == keys) & (p_p < payload))
      take_partner = jnp.where(want_max, partner_bigger, ~partner_bigger)
      keys = jnp.where(take_partner, k_p, keys)
      payload = jnp.where(take_partner, p_p, payload)
      stride //= 2
    size *= 2
  return keys, payload


def _isotonic_minimax(y: Array) -> Array:
  """Non-increasing isotonic fit, closed form; y: (T, E) -> (T, E)."""
  e = y.shape[-1]
  c = jnp.cumsum(y, axis=-1)
  c = jnp.concatenate([jnp.zeros_like(c[..., :1]), c], axis=-1)
  hi = c[..., 1:][..., None, :]                    # (T, 1, E) by k
  lo = c[..., :e][..., :, None]                    # (T, E, 1) by j
  j = jnp.arange(e, dtype=jnp.int32)[:, None]
  k = jnp.arange(e, dtype=jnp.int32)[None, :]
  length = jnp.maximum(k - j + 1, 1).astype(y.dtype)
  gamma = (hi - lo) / length
  g = jnp.where(j <= k, gamma, _NEG)
  inner = jnp.flip(
      jax.lax.cummax(jnp.flip(g, axis=-1), axis=g.ndim - 1), axis=-1)
  masked = jnp.where(j <= k, inner, -_NEG)
  return jnp.min(masked, axis=-2)


def _soft_topk_kernel(z_ref, o_ref, *, k: int, n_real: int):
  z = z_ref[...].astype(jnp.float32)  # (T, E) — E already a power of two
  t, e = z.shape
  idx = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32), (t, e))
  # Padded lanes (>= n_real) hold -inf so they sort to the tail.
  lane = jnp.arange(e, dtype=jnp.int32)
  z_in = jnp.where(lane < n_real, z, _NEG)

  s, sigma = _bitonic(z_in, idx, descending=True)
  w = (lane < k).astype(jnp.float32)               # sorted weights 1^k 0^..
  v = _isotonic_minimax(s - w)
  # Un-permute: sort (sigma asc) carrying v as payload.
  _, v_inv = _bitonic(sigma.astype(jnp.float32), v, descending=False)
  out = z_in - v_inv
  o_ref[...] = jnp.where(lane < n_real, out, 0.0).astype(o_ref.dtype)


def _next_pow2(n: int) -> int:
  p = 1
  while p < n:
    p *= 2
  return p


@functools.partial(
    jax.jit, static_argnames=("k", "token_tile", "interpret"))
def soft_topk_gates(
    logits: Array,
    k: int,
    regularization_strength: float = 1.0,
    *,
    token_tile: int = DEFAULT_TOKEN_TILE,
    interpret: bool | None = None,
) -> Array:
  """Fused soft top-k gate mass for each row of `logits` (T, E).

  Returns gates in [0, 1]^E summing to k per row (fractional memberships of
  the k-subset polytope).  Equivalent to
  ``core.soft_topk_mask(logits, k, eps)``.
  """
  if interpret is None:
    interpret = jax.default_backend() != "tpu"
  t, e = logits.shape
  e_pad = _next_pow2(max(e, 2))
  z = (logits / regularization_strength).astype(jnp.float32)
  if e_pad != e:
    z = jnp.concatenate(
        [z, jnp.full((t, e_pad - e), _NEG, jnp.float32)], axis=-1)
  pad_t = (-t) % token_tile
  if pad_t:
    z = jnp.concatenate([z, jnp.zeros((pad_t, e_pad), jnp.float32)], 0)

  grid = (z.shape[0] // token_tile,)
  spec = pl.BlockSpec((token_tile, e_pad), lambda i: (i, 0))
  out = pl.pallas_call(
      functools.partial(_soft_topk_kernel, k=k, n_real=e),
      out_shape=jax.ShapeDtypeStruct(z.shape, jnp.float32),
      grid=grid,
      in_specs=[spec],
      out_specs=spec,
      interpret=interpret,
  )(z)
  return out[:t, :e].astype(logits.dtype)

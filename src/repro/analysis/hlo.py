"""While-loop-aware cost model over post-SPMD HLO text.

``compiled.cost_analysis()`` does NOT scale loop bodies by their trip count
(verified empirically: a 10x ``lax.scan`` reports 1/10th the FLOPs of the
unrolled program), and it reports no collective bytes at all.  Since every
model here scans over layers, we parse ``compiled.as_text()`` ourselves:

  * computations are parsed into per-instruction records with a symbol
    table (operand shapes resolved by name);
  * ``while`` ops multiply (body + condition) cost by the trip count read
    from ``backend_config={"known_trip_count":{"n":...}}`` (fallback:
    largest integer constant compared against in the condition);
  * dot FLOPs = 2 * |output| * |contracted dims| (from
    ``lhs_contracting_dims`` + the lhs operand's shape);
  * fusion FLOPs recurse into the called computation (1 flop/elem for
    elementwise ops); HBM traffic counts the *call site's* operands +
    results only (fusion internals are VMEM-resident);
  * collective bytes = sum of operand sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (per the assignment's
    link model), x trip count when inside loops.

Everything reported is PER DEVICE (the compiled module is the per-device
SPMD program).
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_ZERO_COST_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "opt-barrier", "custom-call", "infeed", "outfeed", "domain",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes_elems(shape_str: str) -> tuple[int, int]:
  """Total (bytes, elements) over possibly-tuple shape strings."""
  total_b = total_e = 0
  for dtype, dims in _SHAPE_RE.findall(shape_str):
    if dtype not in _DTYPE_BYTES:
      continue
    elems = 1
    if dims:
      for d in dims.split(","):
        elems *= int(d)
    total_e += elems
    total_b += elems * _DTYPE_BYTES[dtype]
  return total_b, total_e


@dataclasses.dataclass
class Instr:
  name: str
  shape: str
  opcode: str
  operands: list[str]
  attrs: str


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")


def _split_shape_op(rest: str) -> tuple[str, str, str, str] | None:
  """rest: '<shape> <opcode>(<operands>)<attrs>'."""
  rest = rest.strip()
  if rest.startswith("("):
    depth = 0
    for i, ch in enumerate(rest):
      depth += ch == "("
      depth -= ch == ")"
      if depth == 0:
        shape, tail = rest[:i + 1], rest[i + 1:]
        break
    else:
      return None
  else:
    sp = rest.find(" ")
    if sp < 0:
      return None
    shape, tail = rest[:sp], rest[sp:]
  tail = tail.strip()
  m = re.match(r"([\w\-]+)\(", tail)
  if not m:
    return None
  opcode = m.group(1)
  depth = 0
  start = tail.find("(")
  for i in range(start, len(tail)):
    depth += tail[i] == "("
    depth -= tail[i] == ")"
    if depth == 0:
      operands = tail[start + 1:i]
      attrs = tail[i + 1:]
      return shape, opcode, operands, attrs
  return None


def _operand_names(operands: str) -> list[str]:
  names, depth, cur = [], 0, []
  for ch in operands + ",":
    if ch == "," and depth == 0:
      tok = "".join(cur).strip()
      cur = []
      if tok:
        m = re.search(r"%([\w.\-]+)\s*$", tok)
        names.append(m.group(1) if m else tok)
      continue
    depth += ch in "([{"
    depth -= ch in ")]}"
    cur.append(ch)
  return names


def parse_computations(text: str) -> dict[str, list[Instr]]:
  comps: dict[str, list[Instr]] = {}
  cur_name = None
  cur: list[Instr] = []
  for line in text.splitlines():
    stripped = line.strip()
    m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{$",
                 stripped)
    if m and not line.startswith("  "):
      cur_name = m.group(1)
      cur = []
      comps[cur_name] = cur
      continue
    if stripped == "}":
      cur_name = None
      continue
    if cur_name is None:
      continue
    im = _INSTR_RE.match(line)
    if not im:
      continue
    split = _split_shape_op(im.group(2))
    if split is None:
      continue
    shape, opcode, operands, attrs = split
    cur.append(Instr(im.group(1), shape, opcode,
                     _operand_names(operands), attrs))
  return comps


def entry_name(text: str) -> str:
  m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
  if not m:
    raise ValueError("no ENTRY computation found")
  return m.group(1)


@dataclasses.dataclass
class Cost:
  flops: float = 0.0
  bytes: float = 0.0
  collective_bytes: float = 0.0
  collectives: dict[str, float] = dataclasses.field(default_factory=dict)
  notes: list[str] = dataclasses.field(default_factory=list)

  def add(self, other: "Cost", mult: float = 1.0):
    self.flops += other.flops * mult
    self.bytes += other.bytes * mult
    self.collective_bytes += other.collective_bytes * mult
    for k, v in other.collectives.items():
      self.collectives[k] = self.collectives.get(k, 0.0) + v * mult
    for n in other.notes:
      if n not in self.notes:
        self.notes.append(n)


class HloCostModel:

  def __init__(self, text: str):
    self.text = text
    self.comps = parse_computations(text)
    self.entry = entry_name(text)
    self._memo: dict[tuple[str, bool], Cost] = {}

  # -- helpers ------------------------------------------------------------

  def _symtab(self, comp: list[Instr]) -> dict[str, str]:
    return {i.name: i.shape for i in comp}

  def _trip_count(self, instr: Instr) -> float:
    m = re.search(r'known_trip_count[":{]+n["\s:]+(\d+)', instr.attrs)
    if m:
      return float(m.group(1))
    # fallback: largest integer constant in the condition computation
    cm = re.search(r"condition=%([\w.\-]+)", instr.attrs)
    if cm:
      pat = re.findall(r"constant\((\d+)\)", self._raw_comp(cm.group(1)))
      if pat:
        return float(max(int(x) for x in pat))
    return 1.0

  def _param_chain(self, comp: list[Instr]) -> dict[str, int]:
    """Map instruction name -> parameter index, following bitcast/reshape
    chains (layout-preserving aliases of the fusion's parameters)."""
    chain: dict[str, int] = {}
    for i in comp:
      if i.opcode == "parameter" and i.operands:
        try:
          chain[i.name] = int(i.operands[0])
        except ValueError:
          pass
    changed = True
    while changed:
      changed = False
      for i in comp:
        if i.opcode in ("bitcast", "reshape", "copy") and i.operands:
          src = i.operands[0]
          if src in chain and i.name not in chain:
            chain[i.name] = chain[src]
            changed = True
    return chain

  def _sliced_params(self, comp_name: str) -> set[int]:
    """Parameter indices consumed via dynamic-slice/gather/d-u-s inside a
    fused computation (their traffic is the slice, not the full buffer)."""
    comp = self.comps.get(comp_name, [])
    chain = self._param_chain(comp)
    out: set[int] = set()
    for i in comp:
      if i.opcode in ("dynamic-slice", "gather", "dynamic-update-slice"):
        if i.operands and i.operands[0] in chain:
          out.add(chain[i.operands[0]])
    return out

  def _inplace_out_bytes(self, comp_name: str) -> float:
    """Bytes of dynamic-update-slice result buffers inside a fused
    computation whose updated operand is (a bitcast of) a fusion
    parameter — these alias in place; only the update slice moves."""
    comp = self.comps.get(comp_name, [])
    chain = self._param_chain(comp)
    total = 0.0
    for i in comp:
      if i.opcode == "dynamic-update-slice" and i.operands and (
          i.operands[0] in chain):
        total += _shape_bytes_elems(i.shape)[0]
    return total

  def _raw_comp(self, name: str) -> str:
    m = re.search(
        rf"^(?:ENTRY\s+)?%?{re.escape(name)}\s*\(.*?\{{(.*?)^\}}",
        self.text, re.M | re.S)
    return m.group(1) if m else ""

  def _dot_flops(self, instr: Instr, symtab: dict[str, str]) -> float:
    _, out_elems = _shape_bytes_elems(instr.shape)
    lhs_shape = symtab.get(instr.operands[0], "")
    mm = _SHAPE_RE.search(lhs_shape)
    contract = 1.0
    if mm:
      dims = [int(d) for d in mm.group(2).split(",") if d]
      cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
      if cm and cm.group(1):
        for idx in cm.group(1).split(","):
          i = int(idx)
          if i < len(dims):
            contract *= dims[i]
    return 2.0 * out_elems * contract

  # -- cost of one computation --------------------------------------------

  def comp_cost(self, name: str, in_fusion: bool = False) -> Cost:
    key = (name, in_fusion)
    if key in self._memo:
      return self._memo[key]
    cost = Cost()
    comp = self.comps.get(name, [])
    symtab = self._symtab(comp)
    for instr in comp:
      cost.add(self.instr_cost(instr, symtab, in_fusion))
    self._memo[key] = cost
    return cost

  def instr_cost(self, instr: Instr, symtab: dict[str, str],
                 in_fusion: bool) -> Cost:
    c = Cost()
    op = instr.opcode
    out_bytes, out_elems = _shape_bytes_elems(instr.shape)
    opnd_bytes = sum(_shape_bytes_elems(symtab.get(o, ""))[0]
                     for o in instr.operands)

    base = op.replace("-start", "").replace("-done", "")
    if op.endswith("-done"):
      return c  # counted at -start
    if base in COLLECTIVE_OPS:
      c.collective_bytes += opnd_bytes
      c.collectives[base] = c.collectives.get(base, 0.0) + opnd_bytes
      c.bytes += opnd_bytes + out_bytes
      return c

    if op in _ZERO_COST_OPS:
      if op == "custom-call":
        c.notes.append(f"custom-call uncosted: {instr.name}")
      return c

    if op == "while":
      trips = self._trip_count(instr)
      bm = re.search(r"body=%([\w.\-]+)", instr.attrs)
      cm = re.search(r"condition=%([\w.\-]+)", instr.attrs)
      if bm:
        c.add(self.comp_cost(bm.group(1)), trips)
      if cm:
        c.add(self.comp_cost(cm.group(1)), trips)
      return c

    if op == "conditional":
      for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                           r"true_computation=%([\w.\-]+)|"
                           r"false_computation=%([\w.\-]+))", instr.attrs):
        for g in m.groups():
          if not g:
            continue
          for nm in g.split(","):
            nm = nm.strip().lstrip("%")
            if nm in self.comps:
              c.add(self.comp_cost(nm))
      return c

    if op == "call":
      m = re.search(r"to_apply=%([\w.\-]+)", instr.attrs)
      if m:
        c.add(self.comp_cost(m.group(1)))
      return c

    if op == "fusion":
      m = re.search(r"calls=%([\w.\-]+)", instr.attrs)
      if m:
        called = m.group(1)
        inner = self.comp_cost(called, in_fusion=True)
        c.flops += inner.flops
        c.notes.extend(inner.notes)
        # HBM traffic: result + inner slice/gather traffic + full reads of
        # the operands NOT consumed through a dynamic-slice/gather (those
        # touch only the moved slice — in-place on TPU, and counted by
        # inner.bytes).  This is what makes scan-carried parameter stacks
        # cost one layer per iteration instead of the whole stack.
        sliced = self._sliced_params(called)
        extra = sum(
            _shape_bytes_elems(symtab.get(o, ""))[0]
            for i, o in enumerate(instr.operands) if i not in sliced)
        # dynamic-update-slice outputs alias their input buffer in place:
        # only the update slice moves (already counted by inner.bytes), so
        # exclude the updated buffers from the fusion's output traffic.
        inplace = self._inplace_out_bytes(called)
        c.bytes += max(out_bytes - inplace, 0.0) + inner.bytes + extra
      else:
        c.bytes += opnd_bytes + out_bytes
      return c

    if op in ("dynamic-slice", "gather"):
      c.flops += out_elems
      c.bytes += 2.0 * out_bytes
      return c

    if op == "dynamic-update-slice":
      upd = (_shape_bytes_elems(symtab.get(instr.operands[1], ""))[0]
             if len(instr.operands) > 1 else out_bytes)
      c.flops += upd / 4.0
      c.bytes += 2.0 * upd
      return c

    if op == "scatter":
      upd = (_shape_bytes_elems(symtab.get(instr.operands[2], ""))[0]
             if len(instr.operands) > 2 else out_bytes)
      c.flops += upd / 4.0
      c.bytes += 3.0 * upd
      return c

    if op == "dot":
      c.flops += self._dot_flops(instr, symtab)
      if not in_fusion:
        c.bytes += opnd_bytes + out_bytes
      return c

    if op == "convolution":
      # not used by these models; approximate as output-elems (flagged)
      c.flops += 2.0 * out_elems
      c.notes.append("convolution approximated")
      c.bytes += 0 if in_fusion else opnd_bytes + out_bytes
      return c

    if op in ("reduce", "reduce-window"):
      _, in_elems = _shape_bytes_elems(symtab.get(
          instr.operands[0], "")) if instr.operands else (0, out_elems)
      c.flops += max(in_elems, out_elems)
      if not in_fusion:
        c.bytes += opnd_bytes + out_bytes
      return c

    if op in ("sort",):
      _, in_elems = _shape_bytes_elems(symtab.get(
          instr.operands[0], "")) if instr.operands else (0, out_elems)
      c.flops += in_elems * max(1.0, math.log2(max(in_elems, 2)))
      c.bytes += opnd_bytes + out_bytes
      return c

    # default: elementwise-ish — 1 flop per output element
    c.flops += out_elems
    if not in_fusion and op in (
        "copy", "transpose", "reshape", "convert", "dynamic-slice",
        "dynamic-update-slice", "slice", "concatenate", "gather",
        "scatter", "pad", "broadcast", "select", "compare", "add",
        "multiply", "subtract", "divide", "tanh", "exponential", "rsqrt",
        "select-and-scatter", "clamp", "maximum", "minimum", "cumsum"):
      c.bytes += opnd_bytes + out_bytes
    return c

  def total(self) -> Cost:
    return self.comp_cost(self.entry)


def analyze_text(text: str) -> dict[str, Any]:
  model = HloCostModel(text)
  cost = model.total()
  return {
      "flops_per_device": cost.flops,
      "hbm_bytes_per_device": cost.bytes,
      "collective_bytes_per_device": cost.collective_bytes,
      "collectives_by_type": dict(cost.collectives),
      "notes": cost.notes,
  }

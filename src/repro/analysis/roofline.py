"""Roofline terms from the compiled dry-run (TPU v5e constants).

  compute_s    = FLOPs_per_chip / 197e12      (bf16 peak per chip)
  memory_s     = HBM_bytes_per_chip / 819e9
  collective_s = collective_bytes_per_chip / 50e9   (per-link model)

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) with N = active matmul
params; the ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat /
dispatch / masking waste.
"""

from __future__ import annotations

from typing import Any

import jax

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per link (assignment's simple model)


def count_active_params(cfg, params_shape: Any) -> tuple[int, int]:
  """(total, active-matmul) parameter counts from a ShapeDtypeStruct tree."""
  total = active = 0
  flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
  for path, leaf in flat:
    pstr = "/".join(str(getattr(k, "key", k)) for k in path)
    n = 1
    for d in leaf.shape:
      n *= d
    total += n
    if "embed/table" in pstr and not cfg.tie_embeddings:
      continue  # pure lookup, no matmul
    if len(leaf.shape) < 2:
      continue
    if "/we_" in pstr:
      # routed experts: only k of E active per token
      n = n * cfg.experts_per_token // max(cfg.num_experts, 1)
    active += n
  return total, active


def model_flops(cfg, params_shape, shape_cell) -> float:
  _, active = count_active_params(cfg, params_shape)
  if shape_cell.kind == "train":
    tokens = shape_cell.global_batch * shape_cell.seq_len
    return 6.0 * active * tokens
  if shape_cell.kind == "prefill":
    tokens = shape_cell.global_batch * shape_cell.seq_len
    return 2.0 * active * tokens
  # decode: one token per sequence
  return 2.0 * active * shape_cell.global_batch


def roofline_terms(parsed: dict, num_devices: int,
                   model_flops_total: float) -> dict:
  compute_s = parsed["flops_per_device"] / PEAK_FLOPS
  memory_s = parsed["hbm_bytes_per_device"] / HBM_BW
  coll_s = parsed["collective_bytes_per_device"] / LINK_BW
  terms = {"compute_s": compute_s, "memory_s": memory_s,
           "collective_s": coll_s}
  dominant = max(terms, key=terms.get)
  hlo_total = parsed["flops_per_device"] * num_devices
  return {
      **terms,
      "dominant": dominant,
      "bound_s": terms[dominant],
      "model_flops": model_flops_total,
      "hlo_flops_total": hlo_total,
      "useful_flops_ratio": (model_flops_total / hlo_total
                             if hlo_total else 0.0),
      # fraction of the compute roofline actually achieved if the dominant
      # term sets the step time:
      "roofline_fraction": (model_flops_total /
                            (num_devices * PEAK_FLOPS * terms[dominant])
                            if terms[dominant] > 0 else 0.0),
  }

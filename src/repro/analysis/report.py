"""Generate EXPERIMENTS.md tables: §Dry-run / §Roofline from cell JSONs,
plus §Benchmarks / §Dispatch metrics from schema-v1 ``BENCH_*.json``
artifacts (repro.obs.artifacts; see docs/BENCHMARKS.md).

  PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun] \
      [--bench 'BENCH_*.json']
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.obs import artifacts as obs_artifacts


def load_cells(directory: str, mesh: str = "single", tagged: bool = False):
  cells = []
  for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
    rec = json.load(open(path))
    if rec.get("mesh") != mesh:
      continue
    if bool(rec.get("tag")) != tagged:
      continue
    cells.append(rec)
  return cells


def fmt_bytes(b):
  return f"{b / 2**30:.2f}"


def roofline_table(cells) -> str:
  hdr = ("| arch | shape | dominant | compute_s | memory_s | collective_s | "
         "MODEL_FLOPS | useful ratio | roofline frac | mem GiB/dev |\n"
         "|---|---|---|---|---|---|---|---|---|---|\n")
  rows = []
  for rec in cells:
    if rec.get("status") == "skipped":
      rows.append(f"| {rec['arch']} | {rec['shape']} | — skipped: "
                  f"{rec['reason'][:60]}… | | | | | | | |")
      continue
    if rec.get("status") != "ok":
      rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | | | |")
      continue
    r = rec["roofline"]
    mem = rec["memory"]["peak_estimate_bytes"]
    rows.append(
        f"| {rec['arch']} | {rec['shape']} | **{r['dominant'][:-2]}** | "
        f"{r['compute_s']*1e3:.1f}ms | {r['memory_s']*1e3:.1f}ms | "
        f"{r['collective_s']*1e3:.1f}ms | {r['model_flops']:.2e} | "
        f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
        f"{fmt_bytes(mem)} |")
  return hdr + "\n".join(rows)


def dryrun_table(cells, cells_multi) -> str:
  hdr = ("| arch | shape | 16x16 compile | 2x16x16 compile | FLOPs/dev | "
         "HBM GB/dev | coll GB/dev | collectives |\n"
         "|---|---|---|---|---|---|---|---|\n")
  multi = {(r["arch"], r["shape"]): r for r in cells_multi}
  rows = []
  for rec in cells:
    key = (rec["arch"], rec["shape"])
    m = multi.get(key, {})
    if rec.get("status") == "skipped":
      rows.append(f"| {rec['arch']} | {rec['shape']} | skip | skip "
                  f"| | | | noted in DESIGN.md §6 |")
      continue
    if rec.get("status") != "ok":
      rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | |")
      continue
    p = rec["hlo_parsed"]
    colls = ", ".join(f"{k}:{v/1e9:.1f}G"
                      for k, v in sorted(p["collectives_by_type"].items()))
    ok_m = "ok" if m.get("status") == "ok" else m.get("status", "?")
    rows.append(
        f"| {rec['arch']} | {rec['shape']} | ok ({rec['compile_s']:.0f}s) | "
        f"{ok_m} ({m.get('compile_s', 0):.0f}s) | "
        f"{p['flops_per_device']/1e12:.2f}T | "
        f"{p['hbm_bytes_per_device']/1e9:.0f} | "
        f"{p['collective_bytes_per_device']/1e9:.1f} | {colls} |")
  return hdr + "\n".join(rows)


def bench_table(payload: dict) -> str:
  """Markdown table of one BENCH artifact's results (timed + skipped)."""
  hdr = ("| name | backend | shape | us/call | fwd+bwd us | notes |\n"
         "|---|---|---|---|---|---|\n")
  rows = []
  for rec in payload.get("results", []):
    shape = ""
    if "n" in rec or "batch" in rec:
      shape = f"b={rec.get('batch', '?')}, n={rec.get('n', '?')}"
    if "skipped" in rec:
      rows.append(f"| {rec.get('name', '?')} | {rec.get('backend', '—')} | "
                  f"{shape} | — | — | skipped: {rec['skipped'][:60]} |")
      continue
    us = rec.get("fwd_us", rec.get("wall_us"))
    us_txt = f"{us:.1f}" if isinstance(us, (int, float)) else "—"
    bwd = rec.get("fwd_bwd_us")
    bwd_txt = f"{bwd:.1f}" if isinstance(bwd, (int, float)) else "—"
    extra = rec.get("derived", "")
    rows.append(f"| {rec.get('name', '?')} | {rec.get('backend', '—')} | "
                f"{shape} | {us_txt} | {bwd_txt} | {extra} |")
  return hdr + "\n".join(rows)


def metrics_table(payload: dict) -> str:
  """Markdown table of the dispatch counters embedded in an artifact."""
  counters = payload.get("metrics", {}).get("counters", {})
  dispatch = {k: v for k, v in sorted(counters.items())
              if k.startswith("dispatch_")}
  if not dispatch:
    return "_no dispatch counters recorded (REPRO_METRICS disabled?)_"
  hdr = "| counter | value |\n|---|---|\n"
  return hdr + "\n".join(f"| `{k}` | {v} |" for k, v in dispatch.items())


def bench_sections(pattern: str) -> str:
  """§Benchmarks + §Dispatch metrics for every artifact matching pattern."""
  chunks = []
  for path in sorted(glob.glob(pattern)):
    errors = obs_artifacts.validate_file(path)
    if errors:
      chunks.append(f"## §Benchmarks — {os.path.basename(path)}\n\n"
                    f"INVALID artifact:\n" +
                    "\n".join(f"* {e}" for e in errors))
      continue
    payload = json.load(open(path))
    meta = payload["meta"]
    prov = (f"platform `{meta['platform']}`, jax `{meta['jax']}`, "
            f"sha `{meta['git_sha'][:12]}`")
    chunks.append(f"## §Benchmarks — {os.path.basename(path)} ({prov})\n\n"
                  + bench_table(payload)
                  + "\n\n### §Dispatch metrics\n\n" + metrics_table(payload))
  return "\n\n".join(chunks) if chunks else (
      f"_no artifacts match {pattern!r}_")


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--dir", default="experiments/dryrun")
  ap.add_argument("--bench", default=None, metavar="GLOB",
                  help="also render BENCH_*.json artifacts matching GLOB")
  args = ap.parse_args()
  single = load_cells(args.dir, "single")
  multi = load_cells(args.dir, "multi")
  print("## §Dry-run (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = "
        "512 chips)\n")
  print(dryrun_table(single, multi))
  print("\n## §Roofline (single-pod, per assignment)\n")
  print(roofline_table(single))
  if args.bench:
    print()
    print(bench_sections(args.bench))


if __name__ == "__main__":
  main()

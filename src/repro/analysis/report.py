"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from cell JSONs.

  PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(directory: str, mesh: str = "single", tagged: bool = False):
  cells = []
  for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
    rec = json.load(open(path))
    if rec.get("mesh") != mesh:
      continue
    if bool(rec.get("tag")) != tagged:
      continue
    cells.append(rec)
  return cells


def fmt_bytes(b):
  return f"{b / 2**30:.2f}"


def roofline_table(cells) -> str:
  hdr = ("| arch | shape | dominant | compute_s | memory_s | collective_s | "
         "MODEL_FLOPS | useful ratio | roofline frac | mem GiB/dev |\n"
         "|---|---|---|---|---|---|---|---|---|---|\n")
  rows = []
  for rec in cells:
    if rec.get("status") == "skipped":
      rows.append(f"| {rec['arch']} | {rec['shape']} | — skipped: "
                  f"{rec['reason'][:60]}… | | | | | | | |")
      continue
    if rec.get("status") != "ok":
      rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | | | |")
      continue
    r = rec["roofline"]
    mem = rec["memory"]["peak_estimate_bytes"]
    rows.append(
        f"| {rec['arch']} | {rec['shape']} | **{r['dominant'][:-2]}** | "
        f"{r['compute_s']*1e3:.1f}ms | {r['memory_s']*1e3:.1f}ms | "
        f"{r['collective_s']*1e3:.1f}ms | {r['model_flops']:.2e} | "
        f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
        f"{fmt_bytes(mem)} |")
  return hdr + "\n".join(rows)


def dryrun_table(cells, cells_multi) -> str:
  hdr = ("| arch | shape | 16x16 compile | 2x16x16 compile | FLOPs/dev | "
         "HBM GB/dev | coll GB/dev | collectives |\n"
         "|---|---|---|---|---|---|---|---|\n")
  multi = {(r["arch"], r["shape"]): r for r in cells_multi}
  rows = []
  for rec in cells:
    key = (rec["arch"], rec["shape"])
    m = multi.get(key, {})
    if rec.get("status") == "skipped":
      rows.append(f"| {rec['arch']} | {rec['shape']} | skip | skip "
                  f"| | | | noted in DESIGN.md §6 |")
      continue
    if rec.get("status") != "ok":
      rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | |")
      continue
    p = rec["hlo_parsed"]
    colls = ", ".join(f"{k}:{v/1e9:.1f}G"
                      for k, v in sorted(p["collectives_by_type"].items()))
    ok_m = "ok" if m.get("status") == "ok" else m.get("status", "?")
    rows.append(
        f"| {rec['arch']} | {rec['shape']} | ok ({rec['compile_s']:.0f}s) | "
        f"{ok_m} ({m.get('compile_s', 0):.0f}s) | "
        f"{p['flops_per_device']/1e12:.2f}T | "
        f"{p['hbm_bytes_per_device']/1e9:.0f} | "
        f"{p['collective_bytes_per_device']/1e9:.1f} | {colls} |")
  return hdr + "\n".join(rows)


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--dir", default="experiments/dryrun")
  args = ap.parse_args()
  single = load_cells(args.dir, "single")
  multi = load_cells(args.dir, "multi")
  print("## §Dry-run (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = "
        "512 chips)\n")
  print(dryrun_table(single, multi))
  print("\n## §Roofline (single-pod, per assignment)\n")
  print(roofline_table(single))


if __name__ == "__main__":
  main()

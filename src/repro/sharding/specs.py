"""Sharding rules: parameter specs and activation constraints.

Divisibility-aware: a rule names the *preferred* mesh axes per tensor dim;
axes that do not divide the dim fall back to replication (e.g.
recurrentgemma's 10 attention heads or xlstm's 4 cannot shard over a
16-way model axis, so those archs shard head_dim / features instead).

Activation constraints are applied through a context so the same model code
runs un-annotated on CPU tests and fully annotated under the production
mesh (`use_rules(...)`).
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

_STATE = threading.local()


@dataclasses.dataclass
class ShardingRules:
  mesh: Mesh
  data_axes: tuple[str, ...] = ("data",)    # ("pod","data") multi-pod
  model_axis: str = "model"
  seq_shard_activations: bool = False
  fsdp: bool = False

  # ----- helpers -----

  def axis_size(self, name: str) -> int:
    return self.mesh.shape[name]

  def _fit(self, dim: int, axes: tuple[str, ...] | str | None,
           used: set[str] | None = None):
    """Return axes (or prefix) whose product divides dim, else None.

    Axes already consumed by earlier dims of the same spec are skipped."""
    if axes is None:
      return None
    if isinstance(axes, str):
      axes = (axes,)
    if used is not None:
      axes = tuple(a for a in axes if a not in used)
    if not axes:
      return None
    for cut in range(len(axes), 0, -1):
      sub = axes[:cut]
      t = 1
      for a in sub:
        t *= self.axis_size(a)
      if dim % t == 0:
        return sub if len(sub) > 1 else sub[0]
    return None

  def spec(self, shape: tuple[int, ...], wanted: tuple[Any, ...]) -> P:
    assert len(shape) == len(wanted), (shape, wanted)
    used: set[str] = set()
    parts = []
    for d, a in zip(shape, wanted):
      fit = self._fit(d, a, used)
      parts.append(fit)
      if fit is not None:
        used.update((fit,) if isinstance(fit, str) else fit)
    return P(*parts)

  @property
  def dp(self):
    return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

  @property
  def tp(self):
    return self.model_axis


# Parameter rules: (path regex, wanted axes per dim). First match wins.
# `DP` and `TP` are placeholders resolved against the live rules;
# `FSDP` resolves to DP when rules.fsdp else None.
DP, TP, FSDP = "__DP__", "__TP__", "__FSDP__"
# __ALL__: every mesh axis (data axes + model), for embarrassingly
# parallel dims like MoE routing groups or long-context KV sequence dims.
ALL = "__ALL__"

PARAM_RULES: list[tuple[str, tuple[Any, ...]]] = [
    (r".*embed/table$", (TP, FSDP)),               # (vocab, d)
    (r".*lm_head/w$", (FSDP, TP)),                 # (d, vocab)
    (r".*codebook_head_\d+/w$", (FSDP, TP)),       # (d, codebook_vocab)
    (r".*attn/wq$", (FSDP, TP, None)),             # (d, H, Dh)
    (r".*attn/wk$", (FSDP, TP, None)),
    (r".*attn/wv$", (FSDP, TP, None)),
    (r".*attn/wo$", (TP, None, FSDP)),             # (H, Dh, d)
    (r".*mla/wq$", (FSDP, TP, None)),              # (d, H, nope+rope)
    (r".*mla/w_dkv$", (FSDP, None)),               # (d, r+rope)
    (r".*mla/w_uk$", (None, TP, None)),            # (r, H, nope)
    (r".*mla/w_uv$", (None, TP, None)),            # (r, H, v)
    (r".*mla/wo$", (TP, None, FSDP)),              # (H, v, d)
    (r".*ffn/router$", None),                      # (d, E) replicated
    (r".*ffn/we_in$", (TP, FSDP, "__MOE_FF__")),   # (E, d, f): EP over model
    (r".*ffn/we_gate$", (TP, FSDP, "__MOE_FF__")),
    (r".*ffn/we_out$", (TP, "__MOE_FF__", FSDP)),  # (E, f, d)
    (r".*ffn/(shared/)?w_in$", (FSDP, TP)),        # (d, f) dense/shared MLP
    (r".*ffn/(shared/)?w_gate$", (FSDP, TP)),
    (r".*ffn/(shared/)?w_out$", (TP, FSDP)),       # (f, d)
    (r".*rg/(w_x|w_gate)$", (FSDP, TP)),           # (d, lru)
    (r".*rg/w_out$", (TP, FSDP)),                  # (lru, d)
    (r".*rg/(a_param|conv_w.*|gate_w.*|gate_b.*)", None),  # small, replicate
    (r".*lstm/w_(q|k|v)$", (FSDP, None, TP)),      # (d, H, dh): shard dh
    (r".*lstm/.*", None),
    (r".*(norm|scale|bias).*", None),
]


def _resolve(rules: ShardingRules, wanted):
  out = []
  for a in wanted:
    if a == DP:
      out.append(rules.data_axes)
    elif a == TP:
      out.append(rules.model_axis)
    elif a == FSDP:
      out.append(rules.data_axes if rules.fsdp else None)
    elif a == "__ALL__":
      out.append(rules.data_axes + (rules.model_axis,))
    elif a == "__MOE_FF__":
      # expert-ffn dim: use model axis only if expert dim could not take it
      out.append(rules.model_axis)
    else:
      out.append(a)
  return tuple(out)


def param_spec(rules: ShardingRules, path: str, shape: tuple[int, ...]) -> P:
  for pat, wanted in PARAM_RULES:
    if re.match(pat, path):
      if wanted is None:
        return P()
      resolved = _resolve(rules, wanted)
      # Scanned segments stack params with a leading repeats dim (never
      # sharded): left-pad the rule to the actual rank.
      if len(shape) > len(resolved):
        resolved = (None,) * (len(shape) - len(resolved)) + resolved
      elif len(shape) < len(resolved):
        return P()
      spec = rules.spec(shape, resolved)
      # MoE: prefer sharding the expert dim; if it took the model axis,
      # drop model from the ffn dim to avoid double use.
      parts = list(spec)
      seen: set[str] = set()
      for i, s in enumerate(parts):
        names = (s,) if isinstance(s, str) else tuple(s or ())
        if any(n in seen for n in names):
          parts[i] = None
        seen.update(names)
      return P(*parts)
  return P()


def param_specs_tree(rules: ShardingRules, params: Any) -> Any:
  def one(path, leaf):
    pstr = "/".join(str(getattr(k, "key", k)) for k in path)
    return param_spec(rules, pstr, leaf.shape)
  return jax.tree_util.tree_map_with_path(one, params)


# Decode-cache rules: (leaf-name regex, ndim) -> wanted axes. Cache leaves
# are segment-stacked: leading dim = scan repeats (never sharded).
CACHE_RULES: list[tuple[str, int, tuple[Any, ...]]] = [
    # attn KV (r,B,S,H,D): batch over data, sequence over whatever is left
    # (for long_500k's global_batch=1, S takes ALL 512 ways).
    (r"(k|v)$", 5, (None, DP, ALL, None, None)),
    (r"c_kv$", 4, (None, DP, ALL, None)),         # MLA latent (r,B,S,r)
    (r"k_rope$", 4, (None, DP, ALL, None)),
    (r"h$", 3, (None, DP, TP)),                   # rg-lru state (r,B,L)
    (r"conv$", 4, (None, DP, None, TP)),          # rg conv hist (r,B,W,L)
    (r"c$", 5, (None, DP, None, None, TP)),       # mlstm C (r,B,H,dk,dv)
    (r"(c|n|m|h)$", 4, (None, DP, None, TP)),     # per-head vec states
    (r"m$", 3, (None, DP, None)),                 # mlstm stabilizer (r,B,H)
]


def cache_spec(rules: ShardingRules, path: str, shape: tuple[int, ...]) -> P:
  leaf = path.rsplit("/", 1)[-1]
  # Attention KV (reps, B, S, H, D): prefer head sharding (attention stays
  # fully local per device); fall back to sequence sharding (flash-decode
  # combine territory) when the kv-head count cannot take the model axis.
  if len(shape) == 5 and re.search(r"(k|v)$", leaf):
    heads = shape[3]
    if heads % rules.axis_size(rules.model_axis) == 0:
      return rules.spec(shape, _resolve(rules, (None, DP, None, TP, None)))
    return rules.spec(shape, _resolve(rules, (None, DP, ALL, None, None)))
  for pat, ndim, wanted in CACHE_RULES:
    if len(shape) == ndim and re.search(pat, leaf):
      return rules.spec(shape, _resolve(rules, wanted))
  return P()


def cache_specs_tree(rules: ShardingRules, cache: Any) -> Any:
  def one(path, leaf):
    pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)
    return cache_spec(rules, pstr, leaf.shape)
  return jax.tree_util.tree_map_with_path(one, cache)


def batch_specs_tree(rules: ShardingRules, batch: Any) -> Any:
  def one(path, leaf):
    spec = [rules.data_axes] + [None] * (len(leaf.shape) - 1)
    return rules.spec(leaf.shape, tuple(spec))
  return jax.tree_util.tree_map_with_path(one, batch)


def opt_state_specs_tree(rules: ShardingRules, opt_state: Any,
                         param_specs: Any) -> Any:
  """Adam moments mirror the param specs; scalars/history replicated."""

  def adam_specs(adam):
    out = dict(adam)
    out["step"] = P()
    out["m"] = param_specs
    out["v"] = param_specs
    if "norm_history" in adam:
      out["norm_history"] = P()
    return out

  out = {}
  for k, v in opt_state.items():
    if k == "adam":
      out[k] = adam_specs(v)
    elif k == "ef_residual":
      out[k] = param_specs
    else:
      out[k] = jax.tree.map(lambda _: P(), v)
  return out


# ---------------------------------------------------------------------------
# Activation constraints (context-scoped).
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
  prev = getattr(_STATE, "rules", None)
  _STATE.rules = rules
  try:
    yield
  finally:
    _STATE.rules = prev


def current_rules() -> ShardingRules | None:
  return getattr(_STATE, "rules", None)


# Activation kinds -> wanted axes (resolved lazily, divisibility-checked).
_ACT_RULES: dict[str, tuple[Any, ...]] = {
    "moe_groups": (DP, None, None),            # (G, gs, d): groups over DP
    "moe_router": (DP, TP, None),              # (G, gs, E): router math is
                                               # per-token -> split gs over
                                               # model (bounds the O(E^2)
                                               # projection workspace)
    "moe_groups4": (DP, TP, None, None),       # (G, E, cap, d): E aligned
                                               # with model-sharded experts
    "residual": (DP, "__SEQ__", None),         # (B, S, d)
    "residual_decode": (DP, None),             # (B, d)
    "heads": (DP, None, TP, None),             # (B, S, H, Dh)
    "heads_decode": (DP, TP, None),            # (B, H, Dh)
    "kv_cache": (DP, TP, None, None),          # (B, S, Hkv, Dh): seq-shard
    "kv_cache_batch": (DP, None, None, None),  # alt: batch-only
    "logits": (DP, None, TP),                  # (B, S, V)
    "logits_decode": (DP, TP),                 # (B, V)
    "expert_acts": (TP, None, None),           # (E, cap, d)
    "expert_acts4": (DP, TP, None, None),      # (G, E, cap, d)
    "ffn": (DP, None, TP),                     # (B, S, f)
    "rg_state": (DP, TP),                      # (B, lru)
    "mlstm_state": (DP, None, None, TP),       # (B, H, dk, dv)
    "tokens": (DP, None),                      # (B, S)
}


def shard_activation(x: Array, kind: str) -> Array:
  """Apply a named sharding constraint if rules are active, else no-op."""
  rules = current_rules()
  if rules is None:
    return x
  wanted = list(_resolve(rules, _ACT_RULES[kind]))
  # __SEQ__: shard sequence over model axis only when enabled.
  for i, a in enumerate(wanted):
    if a == "__SEQ__":
      wanted[i] = rules.model_axis if rules.seq_shard_activations else None
  if len(wanted) != x.ndim:
    return x
  spec = rules.spec(x.shape, tuple(wanted))
  return jax.lax.with_sharding_constraint(
      x, NamedSharding(rules.mesh, spec))

"""Serializable execution plans: every dispatch decision in one object.

Before this layer, "which implementation runs" was smeared across three
parallel precedence chains (forward backend, backward backend, projection
path), three environment variables, three per-call kwargs, a process
default, and two hardcoded ``auto`` cutoffs inside
``repro.kernels.dispatch``.  An :class:`ExecutionPlan` captures all of it
in one serializable, hashable object:

* an ordered table of :class:`PlanRule` entries, each mapping a
  ``(kind, op, regularization, platform, dtype, shape-bucket)`` regime to
  a concrete backend, where ``kind`` is one of ``"forward"`` (isotonic
  solver), ``"backward"`` (Lemma-2 VJP formulation) or ``"projection"``
  (fused vs composed pipeline);
* JSON round-tripping under schema ``repro.plan/v1`` with strict
  unknown-field and version-mismatch rejection, so a committed plan file
  can be trusted byte-for-byte;
* a content hash (:meth:`ExecutionPlan.plan_hash`) that BENCH artifacts
  embed so every perf row is attributable to the selection that produced
  it.

Resolution (in ``repro.kernels.dispatch``) walks a single chain for all
three decision kinds::

    explicit argument  >  environment variable  >  active plan
                       >  packaged default plan  >  built-in plan

The *active* plan is installed per-process (:func:`set_active_plan`, the
``--plan plan.json`` launch flag) or per-scope (:func:`use_plan`); the
*packaged default plan* is ``src/repro/plan/default_plan.json``, emitted
by ``tools/autotune.py`` from measured ``BENCH_*.json`` sweeps (every
rule carries the timing-row names that justify it — validated in CI by
``tools/check_backends.py --plan``); the *built-in* plan is the
shape-oblivious safety net (TPU -> pallas, small-n -> minimax under a
memory cap, otherwise scan; segscan backward; fused projection) and is
total — some rule always matches.

This module is deliberately light: stdlib + ``repro.obs.metrics`` only
(no jax), so tools can load and validate plans without pulling in the
accelerator stack.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
from typing import Iterable

SCHEMA_VERSION = "repro.plan/v1"

KINDS = ("forward", "backward", "projection")

# Shape-regime constants for the built-in plan (formerly hardcoded in
# repro.kernels.dispatch as AUTO_MINIMAX_MAX_N / AUTO_MINIMAX_MAX_ELEMS):
# n at or below which the O(n^2) closed form is allowed to win, and the
# rows * n^2 f32-element cap (~64 MB) past which it must not be picked
# regardless of n (the large-flattened-batch MoE-router regime).
BUILTIN_MINIMAX_MAX_N = 64
BUILTIN_MINIMAX_MAX_ELEMS = 16_000_000

_RULE_FIELDS = ("kind", "backend", "op", "regularization", "platform",
                "dtype", "min_n", "max_n", "min_rows", "max_rows",
                "max_elems", "evidence")
_PLAN_FIELDS = ("schema", "name", "rules", "meta")


@dataclasses.dataclass(frozen=True)
class PlanRule:
  """One regime -> backend entry of an execution plan.

  A rule *matches* a decision query when every constraint holds; ``"*"``
  (the default for the categorical keys) matches anything.  The shape
  bucket is expressed as optional inclusive bounds on ``n`` (last-axis
  problem size), ``rows`` (flattened batch rows) and ``rows * n^2``
  (``max_elems``, the minimax memory bill).  A rule with any shape
  constraint never matches a shapeless query — so a plan can never route
  an unknown-size problem to a size-gated backend (the old
  shape=None -> minimax bug class is unrepresentable).
  """

  kind: str
  backend: str
  op: str = "*"
  regularization: str = "*"
  platform: str = "*"
  dtype: str = "*"
  min_n: int | None = None
  max_n: int | None = None
  min_rows: int | None = None
  max_rows: int | None = None
  max_elems: int | None = None
  evidence: tuple[str, ...] = ()

  def __post_init__(self):
    if self.kind not in KINDS:
      raise ValueError(f"rule kind must be one of {KINDS}, got {self.kind!r}")
    if not self.backend or not isinstance(self.backend, str):
      raise ValueError(f"rule backend must be a non-empty string, "
                       f"got {self.backend!r}")
    object.__setattr__(self, "evidence", tuple(self.evidence))

  def shape_constrained(self) -> bool:
    return any(v is not None for v in (self.min_n, self.max_n,
                                       self.min_rows, self.max_rows,
                                       self.max_elems))

  def matches(self, kind: str, op: str, regularization: str, *,
              platform: str, dtype: str,
              shape: tuple[int, ...] | None) -> bool:
    if self.kind != kind:
      return False
    for want, have in ((self.op, op), (self.regularization, regularization),
                       (self.platform, platform), (self.dtype, dtype)):
      if want != "*" and have is not None and want != have:
        return False
    if not self.shape_constrained():
      return True
    if shape is None:
      # Unknown shape must not satisfy a size-gated rule.
      return False
    n = shape[-1]
    rows = 1
    for d in shape[:-1]:
      rows *= d
    if self.min_n is not None and n < self.min_n:
      return False
    if self.max_n is not None and n > self.max_n:
      return False
    if self.min_rows is not None and rows < self.min_rows:
      return False
    if self.max_rows is not None and rows > self.max_rows:
      return False
    if self.max_elems is not None and rows * n * n > self.max_elems:
      return False
    return True

  def to_dict(self) -> dict:
    out = {"kind": self.kind, "backend": self.backend}
    for k in ("op", "regularization", "platform", "dtype"):
      v = getattr(self, k)
      if v != "*":
        out[k] = v
    for k in ("min_n", "max_n", "min_rows", "max_rows", "max_elems"):
      v = getattr(self, k)
      if v is not None:
        out[k] = v
    if self.evidence:
      out["evidence"] = list(self.evidence)
    return out

  @classmethod
  def from_dict(cls, d: dict) -> "PlanRule":
    if not isinstance(d, dict):
      raise ValueError(f"plan rule must be an object, got {type(d).__name__}")
    unknown = sorted(set(d) - set(_RULE_FIELDS))
    if unknown:
      raise ValueError(f"plan rule has unknown field(s) {unknown}; "
                       f"known fields: {sorted(_RULE_FIELDS)}")
    for k in ("kind", "backend"):
      if k not in d:
        raise ValueError(f"plan rule missing required field {k!r}")
    kwargs = dict(d)
    if "evidence" in kwargs:
      ev = kwargs["evidence"]
      if (not isinstance(ev, (list, tuple))
          or not all(isinstance(e, str) for e in ev)):
        raise ValueError("plan rule 'evidence' must be a list of strings")
      kwargs["evidence"] = tuple(ev)
    return cls(**kwargs)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
  """An ordered, serializable backend-selection table (first match wins).

  Hashable (``meta`` is excluded from equality/hash), so a plan can ride
  through ``jax.custom_vjp`` non-differentiable arguments and jit static
  arguments without ceremony.
  """

  name: str = "unnamed"
  rules: tuple[PlanRule, ...] = ()
  meta: dict = dataclasses.field(default_factory=dict, compare=False)

  def __post_init__(self):
    object.__setattr__(self, "rules", tuple(self.rules))

  def decide(self, kind: str, op: str, regularization: str, *,
             platform: str, dtype: str = "*",
             shape: tuple[int, ...] | None = None) -> PlanRule | None:
    """First rule matching the query, or None when the plan is silent."""
    for rule in self.rules:
      if rule.matches(kind, op, regularization, platform=platform,
                      dtype=dtype, shape=shape):
        return rule
    return None

  # -- serialization --------------------------------------------------------

  def to_dict(self) -> dict:
    return {
        "schema": SCHEMA_VERSION,
        "name": self.name,
        "rules": [r.to_dict() for r in self.rules],
        "meta": dict(self.meta),
    }

  def to_json(self, indent: int | None = 2) -> str:
    return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

  @classmethod
  def from_dict(cls, d: dict) -> "ExecutionPlan":
    if not isinstance(d, dict):
      raise ValueError(f"plan must be an object, got {type(d).__name__}")
    schema = d.get("schema")
    if schema != SCHEMA_VERSION:
      raise ValueError(f"plan schema mismatch: expected {SCHEMA_VERSION!r}, "
                       f"got {schema!r}")
    unknown = sorted(set(d) - set(_PLAN_FIELDS))
    if unknown:
      raise ValueError(f"plan has unknown field(s) {unknown}; "
                       f"known fields: {sorted(_PLAN_FIELDS)}")
    rules = d.get("rules", [])
    if not isinstance(rules, list):
      raise ValueError("plan 'rules' must be a list")
    meta = d.get("meta", {})
    if not isinstance(meta, dict):
      raise ValueError("plan 'meta' must be an object")
    return cls(name=d.get("name", "unnamed"),
               rules=tuple(PlanRule.from_dict(r) for r in rules),
               meta=dict(meta))

  @classmethod
  def from_json(cls, text: str) -> "ExecutionPlan":
    try:
      d = json.loads(text)
    except json.JSONDecodeError as e:
      raise ValueError(f"plan is not valid JSON: {e}") from e
    return cls.from_dict(d)

  def save(self, path: str) -> None:
    with open(path, "w") as f:
      f.write(self.to_json())
      f.write("\n")

  def plan_hash(self) -> str:
    """Content hash over (schema, name, rules) — stable across re-emits
    with identical decisions (``meta`` provenance is excluded)."""
    canonical = json.dumps(
        {"schema": SCHEMA_VERSION, "name": self.name,
         "rules": [r.to_dict() for r in self.rules]},
        sort_keys=True, separators=(",", ":"))
    return hashlib.sha1(canonical.encode()).hexdigest()[:12]


def load_plan(path: str) -> ExecutionPlan:
  """Load and strictly validate a plan file (raises ValueError on any
  schema/shape problem, OSError if unreadable)."""
  with open(path) as f:
    return ExecutionPlan.from_json(f.read())


# ---------------------------------------------------------------------------
# Built-in plan: the shape-oblivious safety net (total coverage).
# ---------------------------------------------------------------------------


def builtin_plan() -> ExecutionPlan:
  """The constants-derived fallback plan, matching every possible query.

  Encodes the pre-plan ``auto`` behavior: TPU -> ``pallas``; off-TPU the
  O(n^2) ``minimax`` closed form for small n under its memory cap; the
  log-depth ``scan`` machine otherwise (including all shapeless queries);
  ``segscan`` backward; ``fused`` projection.
  """
  return _BUILTIN


_BUILTIN = ExecutionPlan(
    name="builtin",
    rules=(
        PlanRule("forward", "pallas", op="isotonic", platform="tpu"),
        PlanRule("forward", "minimax", op="isotonic",
                 max_n=BUILTIN_MINIMAX_MAX_N,
                 max_elems=BUILTIN_MINIMAX_MAX_ELEMS),
        PlanRule("forward", "scan", op="isotonic"),
        PlanRule("backward", "segscan"),
        PlanRule("projection", "fused", op="projection"),
    ),
)


# ---------------------------------------------------------------------------
# Packaged default plan (emitted by tools/autotune.py, committed).
# ---------------------------------------------------------------------------

DEFAULT_PLAN_PATH = os.path.join(os.path.dirname(__file__),
                                 "default_plan.json")

_default_cache: list = []  # [plan-or-None] once loaded


def default_plan() -> ExecutionPlan | None:
  """The committed autotuned plan, or None when absent/invalid.

  Loaded once per process; a missing or unparsable file silently falls
  back to :func:`builtin_plan` at resolution time (CI separately *fails*
  on an invalid committed plan via ``tools/check_backends.py --plan`` —
  runtime just refuses to crash the import path over it).
  """
  if not _default_cache:
    try:
      _default_cache.append(load_plan(DEFAULT_PLAN_PATH))
    except (OSError, ValueError):
      _default_cache.append(None)
  return _default_cache[0]


def invalidate_default_plan_cache() -> None:
  """Forget the cached packaged plan (tests / after re-autotuning)."""
  _default_cache.clear()


# ---------------------------------------------------------------------------
# Active plan: process-wide slot + scoped override.
# ---------------------------------------------------------------------------

_ACTIVE: list[ExecutionPlan | None] = [None]


def get_active_plan() -> ExecutionPlan | None:
  return _ACTIVE[0]


def set_active_plan(plan: ExecutionPlan | None) -> None:
  """Install ``plan`` as the process-wide active plan (None clears it).

  This is what ``launch/{train,serve}.py --plan plan.json`` calls; plan
  consultation happens at Python trace time, so an installed plan governs
  everything traced afterwards.
  """
  if plan is not None and not isinstance(plan, ExecutionPlan):
    raise TypeError(f"expected ExecutionPlan or None, got {type(plan)}")
  _ACTIVE[0] = plan


@contextlib.contextmanager
def use_plan(plan: ExecutionPlan | None):
  """Scoped :func:`set_active_plan` (trace-time only: like the old
  ``use_backend``, lazily-traced custom_vjp rules may fire after the
  scope exits — pass ``plan=`` / ``impl=`` explicitly under jit)."""
  prev = _ACTIVE[0]
  set_active_plan(plan)
  try:
    yield
  finally:
    _ACTIVE[0] = prev


def resolve_via_plans(
    kind: str, op: str, regularization: str, *, platform: str,
    dtype: str = "*", shape: tuple[int, ...] | None = None,
    plan: ExecutionPlan | None = None,
) -> tuple[str, str, PlanRule]:
  """Walk the plan chain for one decision: (backend, source, rule).

  Chain: the explicit per-call ``plan`` (else the active plan, source
  ``"plan"``) > the packaged default plan (``"default_plan"``) > the
  built-in plan (``"builtin"``).  The built-in plan is total, so this
  always returns.
  """
  chain: Iterable[tuple[str, ExecutionPlan | None]] = (
      ("plan", plan if plan is not None else get_active_plan()),
      ("default_plan", default_plan()),
      ("builtin", builtin_plan()),
  )
  for source, candidate in chain:
    if candidate is None:
      continue
    rule = candidate.decide(kind, op, regularization, platform=platform,
                            dtype=dtype, shape=shape)
    if rule is not None:
      _plan_decide_note(kind, rule.backend, source, candidate.name)
      return rule.backend, source, rule
  raise AssertionError(
      f"builtin plan failed to cover kind={kind!r} op={op!r} "
      f"regularization={regularization!r} platform={platform!r}")


def _plan_decide_note(kind: str, backend: str, source: str,
                      plan_name: str) -> None:
  from repro.obs import metrics as _metrics  # lazy: keep import cheap
  _metrics.counter_inc("plan_decide", kind=kind, backend=backend,
                       source=source, plan=plan_name)


def _governing_plans(plan: ExecutionPlan | None) -> tuple[ExecutionPlan, ...]:
  """The plan chain as it would be consulted right now, most-specific
  first (explicit/active > packaged default > builtin), Nones dropped."""
  chain = (plan if plan is not None else get_active_plan(),
           default_plan(), builtin_plan())
  return tuple(p for p in chain if p is not None)


def shape_breakpoints(plan: ExecutionPlan | None = None) -> tuple[int, ...]:
  """Sorted unique n-edges at which some rule's applicability flips.

  For every shape-constrained rule in the governing plan chain, the
  inclusive bounds ``max_n`` and ``min_n - 1`` are bucket edges: a
  serving bucket whose width crosses one would pad requests from one
  backend regime into another.  ``repro.serving.BucketPolicy.from_plan``
  splices these into its size ladder.
  """
  edges: set[int] = set()
  for candidate in _governing_plans(plan):
    for rule in candidate.rules:
      if rule.max_n is not None:
        edges.add(rule.max_n)
      if rule.min_n is not None and rule.min_n > 1:
        edges.add(rule.min_n - 1)
  return tuple(sorted(e for e in edges if e >= 1))


def resolve_grid(
    kind: str,
    ops: Iterable[str],
    regularizations: Iterable[str],
    shapes: Iterable[tuple[int, ...]],
    *,
    platform: str,
    dtype: str = "*",
    plan: ExecutionPlan | None = None,
) -> list[dict]:
  """Enumerate plan decisions over an (op x regularization x shape) grid.

  The serving engine's warmup uses this to know, ahead of any traffic,
  which backend each AOT-compiled bucket will embed — one entry per grid
  cell: ``{kind, op, regularization, shape, backend, source, plan}``.
  Unlike :func:`resolve_via_plans` this never records ``plan_decide``
  counters (it is an enumeration, not a dispatch decision).
  """
  shapes = [tuple(s) for s in shapes]
  out: list[dict] = []
  for op in ops:
    for reg in regularizations:
      for shape in shapes:
        for source_name, candidate in (
            ("plan", plan if plan is not None else get_active_plan()),
            ("default_plan", default_plan()),
            ("builtin", builtin_plan())):
          if candidate is None:
            continue
          rule = candidate.decide(kind, op, reg, platform=platform,
                                  dtype=dtype, shape=shape)
          if rule is not None:
            out.append({"kind": kind, "op": op, "regularization": reg,
                        "shape": shape, "backend": rule.backend,
                        "source": source_name, "plan": candidate.name})
            break
  return out


def plan_provenance(plan: ExecutionPlan | None = None) -> dict:
  """Attribution block for BENCH artifact ``meta``: which plan governs
  dispatch right now (explicit > active > packaged default > builtin)
  and its content hash, so perf rows are attributable to the selection
  that produced them."""
  for source, candidate in (
      ("arg", plan), ("plan", get_active_plan()),
      ("default_plan", default_plan()), ("builtin", builtin_plan())):
    if candidate is not None:
      return {"plan_name": candidate.name,
              "plan_hash": candidate.plan_hash(),
              "plan_source": source}
  raise AssertionError("builtin plan is always available")


__all__ = [
    "SCHEMA_VERSION",
    "KINDS",
    "BUILTIN_MINIMAX_MAX_N",
    "BUILTIN_MINIMAX_MAX_ELEMS",
    "DEFAULT_PLAN_PATH",
    "PlanRule",
    "ExecutionPlan",
    "load_plan",
    "builtin_plan",
    "default_plan",
    "invalidate_default_plan_cache",
    "get_active_plan",
    "set_active_plan",
    "use_plan",
    "resolve_via_plans",
    "resolve_grid",
    "shape_breakpoints",
    "plan_provenance",
]

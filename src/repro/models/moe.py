"""Mixture-of-Experts FFN with a differentiable soft-top-k router.

The router is the framework's flagship integration of the paper: gate
masses come from the projection of logits onto the k-subset permutahedron
(``core.soft_topk_mask`` / the fused Pallas kernel), giving *dense,
nonzero gradients to every expert's logit* — unlike softmax-top-k whose
gradient is zero for unselected experts.  Dispatch stays hard top-k with
capacity (straight-through), so compute is the standard one-hot einsum
dispatch/combine used at scale (MaxText/Mesh-TF style).

Tokens are routed within fixed-size *groups* (``moe_group_size``): the
dense dispatch einsum costs O(group * k * cf * d) FLOPs per token, so the
group size bounds dispatch overhead (~15% of expert FLOPs at 512) while
keeping per-expert capacity statistically stable.

Routers:
  softmax_topk  — standard baseline (softmax over chosen experts)
  soft_topk     — paper technique (projection gate mass, straight-through)
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.isotonic import use_impl  # noqa: F401 (eager-path helper)
from repro.core.operators import soft_topk_mask
from repro.sharding.specs import shard_activation

Array = jax.Array
Params = dict[str, Any]


def moe_init(key, cfg, dtype) -> Params:
  d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
  ks = jax.random.split(key, 5)
  si, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
  p = {
      "router": jax.random.normal(ks[0], (d, e)).astype(jnp.float32) * si,
      "we_in": (jax.random.normal(ks[1], (e, d, f)) * si).astype(dtype),
      "we_gate": (jax.random.normal(ks[2], (e, d, f)) * si).astype(dtype),
      "we_out": (jax.random.normal(ks[3], (e, f, d)) * so).astype(dtype),
  }
  if cfg.num_shared_experts:
    fs = f * cfg.num_shared_experts
    k1, k2, k3 = jax.random.split(ks[4], 3)
    p["shared"] = {
        "w_in": (jax.random.normal(k1, (d, fs)) * si).astype(dtype),
        "w_gate": (jax.random.normal(k2, (d, fs)) * si).astype(dtype),
        "w_out": (jax.random.normal(k3, (fs, d)) * so).astype(dtype),
    }
  return p


def _router_weights(cfg, logits: Array) -> tuple[Array, Array]:
  """logits: (..., E) -> (combine weights, router probs)."""
  k = cfg.experts_per_token
  probs = jax.nn.softmax(logits, axis=-1)
  if cfg.router == "soft_topk":
    # Paper technique: differentiable top-k mass (sums to k per token),
    # with dense gradients to every expert logit.  Router rows are small
    # (E <= 128) and live under SPMD, so use the fully-vectorized minimax
    # solver (no data-dependent loops -> no per-iteration collectives).
    # NB: impl is passed explicitly — custom_vjp fwd rules trace lazily,
    # after any trace-time context manager has exited.
    mask = soft_topk_mask(logits, k, cfg.router_eps, impl="minimax")
    w = mask * probs
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
  else:
    topv = lax.top_k(probs, k)[0]
    w = jnp.where(probs >= topv[..., -1:], probs, 0.0)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
  return w, probs


def _dispatch_mask(weights: Array, k: int, capacity: int):
  """Capacity-bounded top-k dispatch within groups.

  weights: (G, T, E). Returns dispatch/combine one-hots (G, T, E, C).
  """
  g, t, e = weights.shape
  w = weights
  dispatch = jnp.zeros((g, t, e, capacity), weights.dtype)
  combine = jnp.zeros((g, t, e, capacity), weights.dtype)
  fill = jnp.zeros((g, e), jnp.int32)
  for _ in range(k):
    idx = jnp.argmax(lax.stop_gradient(w), axis=-1)        # (G, T)
    onehot = jax.nn.one_hot(idx, e, dtype=weights.dtype)   # (G, T, E)
    rank_in_round = jnp.cumsum(onehot, axis=1) - onehot
    pos = fill[:, None, :] + rank_in_round.astype(jnp.int32)
    pos_t = jnp.sum(pos * onehot.astype(jnp.int32), axis=-1)  # (G, T)
    ok = pos_t < capacity
    poh = jax.nn.one_hot(jnp.where(ok, pos_t, capacity), capacity + 1,
                         dtype=weights.dtype)[..., :capacity]  # (G,T,C)
    d_k = onehot[..., None] * poh[:, :, None, :]           # (G,T,E,C)
    gate = jnp.take_along_axis(w, idx[..., None], axis=-1)  # (G,T,1)
    dispatch = dispatch + d_k
    combine = combine + d_k * gate[..., None]
    fill = fill + jnp.sum(onehot, axis=1).astype(jnp.int32)
    w = w * (1.0 - onehot)
  return dispatch, combine


def load_balance_loss(probs: Array, dispatch: Array) -> Array:
  """Switch-style auxiliary loss: E * <fraction routed, mean prob>."""
  e = probs.shape[-1]
  frac = jnp.mean(jnp.sum(dispatch, axis=-1), axis=(0, 1))   # (E,)
  mean_prob = jnp.mean(probs, axis=(0, 1))
  return e * jnp.sum(frac * mean_prob)


def moe_apply(p: Params, x: Array, cfg) -> tuple[Array, Array]:
  """x: (B,S,d) or (B,d) -> (same shape, aux_loss scalar)."""
  orig_shape = x.shape
  d = x.shape[-1]
  xt = x.reshape(-1, d)
  t_total = xt.shape[0]
  gs = min(cfg.moe_group_size, t_total)
  # pad to a multiple of the group size
  pad = (-t_total) % gs
  if pad:
    xt = jnp.concatenate([xt, jnp.zeros((pad, d), xt.dtype)], 0)
  xg = xt.reshape(-1, gs, d)                                  # (G, gs, d)
  xg = shard_activation(xg, "moe_groups")

  logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
  logits = shard_activation(logits, "moe_router")
  weights, probs = _router_weights(cfg, logits)
  # dispatch needs within-group cumsums: bring tokens back group-local
  weights = shard_activation(weights, "moe_groups")
  k, e = cfg.experts_per_token, cfg.num_experts
  capacity = max(int(math.ceil(gs * k * cfg.capacity_factor / e)), 4)
  dispatch, combine = _dispatch_mask(weights, k, capacity)
  dispatch = dispatch.astype(x.dtype)
  combine = combine.astype(x.dtype)

  xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
  xe = shard_activation(xe, "moe_groups4")
  h = jnp.einsum("gecd,edf->gecf", xe, p["we_in"])
  gg = jnp.einsum("gecd,edf->gecf", xe, p["we_gate"])
  h = jax.nn.silu(gg) * h
  ye = jnp.einsum("gecf,efd->gecd", h, p["we_out"])
  ye = shard_activation(ye, "moe_groups4")
  yt = jnp.einsum("gtec,gecd->gtd", combine, ye)
  yt = shard_activation(yt, "moe_groups")

  if "shared" in p:
    sh = p["shared"]
    hs = jax.nn.silu(jnp.einsum("gtd,df->gtf", xg, sh["w_gate"])) * (
        jnp.einsum("gtd,df->gtf", xg, sh["w_in"]))
    yt = yt + jnp.einsum("gtf,fd->gtd", hs, sh["w_out"])

  aux = load_balance_loss(probs, dispatch.astype(jnp.float32))
  out = yt.reshape(-1, d)[:t_total].reshape(orig_shape)
  return out, aux

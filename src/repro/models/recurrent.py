"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Temporal mixing:  y = W_out( GeLU(W_gate x) * RGLRU(conv1d(W_x x)) )

RG-LRU recurrence (diagonal, gated):
  r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
  log a_t = -c * softplus(Lambda) * r_t           (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (parallel
prefix — the TPU-native way to run linear recurrences, log-depth instead of
S sequential steps).  Decode keeps (h, last conv_width-1 inputs) as state:
O(1) per token — this is what qualifies the arch for the 500k cell.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.specs import shard_activation

Array = jax.Array
Params = dict[str, Any]

_C = 8.0


def rg_init(key, cfg, dtype) -> Params:
  d, l = cfg.d_model, cfg.lru_width or cfg.d_model
  ks = jax.random.split(key, 7)
  si = 1.0 / math.sqrt(d)
  sl = 1.0 / math.sqrt(l)
  # Lambda init so that a ~ Uniform(0.9, 0.999)^c-ish (Griffin appendix).
  u = jax.random.uniform(ks[0], (l,), minval=0.9, maxval=0.999)
  a_param = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^{-1}(-log u / c)
  return {
      "w_x": (jax.random.normal(ks[1], (d, l)) * si).astype(dtype),
      "w_gate": (jax.random.normal(ks[2], (d, l)) * si).astype(dtype),
      "w_out": (jax.random.normal(ks[3], (l, d)) * sl).astype(dtype),
      "a_param": a_param.astype(jnp.float32),
      "gate_w_r": (jax.random.normal(ks[4], (d, l)) * si).astype(dtype),
      "gate_w_i": (jax.random.normal(ks[5], (d, l)) * si).astype(dtype),
      "conv_w": (jax.random.normal(ks[6], (cfg.conv_width, l)) *
                 (1.0 / math.sqrt(cfg.conv_width))).astype(dtype),
  }


def _conv1d_causal(x: Array, w: Array) -> Array:
  """Depthwise causal conv, x: (B,S,L), w: (W,L) — small W, tap-sum form."""
  width = w.shape[0]
  out = x * w[width - 1]
  for i in range(1, width):
    shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
    out = out + shifted * w[width - 1 - i]
  return out


def _rglru_gates(p: Params, x_raw: Array, u: Array):
  """Gate computations shared by scan/step. x_raw: pre-conv input for gates;
  u: conv output entering the recurrence."""
  r = jax.nn.sigmoid(
      jnp.einsum("...d,dl->...l", x_raw, p["gate_w_r"]).astype(jnp.float32))
  i = jax.nn.sigmoid(
      jnp.einsum("...d,dl->...l", x_raw, p["gate_w_i"]).astype(jnp.float32))
  log_a = -_C * jax.nn.softplus(p["a_param"]) * r
  a = jnp.exp(log_a)
  gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9, 1.0)) * (
      i * u.astype(jnp.float32))
  return a, gated


def rg_apply_seq(p: Params, x: Array, cfg, *, return_state: bool = False):
  """Full-sequence RG-LRU block. x: (B,S,d)."""
  xb = jnp.einsum("bsd,dl->bsl", x, p["w_x"])
  gate = jax.nn.gelu(
      jnp.einsum("bsd,dl->bsl", x, p["w_gate"]), approximate=True)
  u = _conv1d_causal(xb, p["conv_w"])
  a, gated = _rglru_gates(p, x, u)

  def combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2

  a_s, h = lax.associative_scan(combine, (a, gated), axis=1)
  h = shard_activation(h.astype(x.dtype), "residual")
  y = jnp.einsum("bsl,ld->bsd", gate * h.astype(gate.dtype), p["w_out"])
  if return_state:
    state = {
        "h": h[:, -1].astype(jnp.float32),
        "conv": xb[:, -(cfg.conv_width - 1):].astype(jnp.float32),
    }
    return y, state
  return y


def rg_init_state(cfg, batch: int, dtype) -> Params:
  l = cfg.lru_width or cfg.d_model
  return {
      "h": jnp.zeros((batch, l), jnp.float32),
      "conv": jnp.zeros((batch, cfg.conv_width - 1, l), jnp.float32),
  }


def rg_apply_decode(p: Params, x: Array, state: Params, cfg):
  """One-token step. x: (B,d); state: {h (B,L), conv (B,W-1,L)}."""
  xb = jnp.einsum("bd,dl->bl", x, p["w_x"])
  gate = jax.nn.gelu(
      jnp.einsum("bd,dl->bl", x, p["w_gate"]), approximate=True)
  width = cfg.conv_width
  hist = jnp.concatenate(
      [state["conv"], xb[:, None].astype(jnp.float32)], axis=1)  # (B,W,L)
  u = jnp.einsum("bwl,wl->bl", hist, p["conv_w"].astype(jnp.float32))
  a, gated = _rglru_gates(p, x, u)
  h = a * state["h"] + gated
  h = shard_activation(h, "rg_state")
  y = jnp.einsum("bl,ld->bd", (gate.astype(jnp.float32) * h).astype(x.dtype),
                 p["w_out"])
  new_state = {"h": h, "conv": hist[:, 1:]}
  return y, new_state

"""Model assembly: layer-kind registry, scanned segments, train/serve passes.

A config's ``block_cycle`` is expanded to per-layer kinds and grouped into
scannable segments (``ArchConfig.plan_segments``): parameters for each
segment are stacked with a leading ``repeats`` dim and the segment runs
under ``lax.scan`` (compact HLO, one compiled body per cycle) with a
configurable remat policy.  Decode threads per-layer caches through the
same scan.

Supported layer kinds:
  dense / global   GQA attention + MLP
  local            sliding-window GQA attention + MLP
  moe              GQA attention + MoE FFN (+ shared experts)
  mla_moe          Multi-head Latent Attention + MoE FFN (deepseek)
  rg               RG-LRU recurrent block + MLP (recurrentgemma)
  mlstm / slstm    xLSTM blocks + MLP
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import recurrent as RG
from repro.models import xlstm as XL
from repro.sharding.specs import shard_activation

Array = jax.Array
Params = dict[str, Any]


def _dtype(cfg):
  return jnp.dtype(cfg.dtype)


def _ffn_variant(cfg, kind) -> str:
  if kind == "mlstm":
    return "gelu"
  if kind == "slstm":
    return "geglu"
  return cfg.mlp_variant


def _ffn_init(key, cfg, kind, dtype):
  if kind in ("moe", "mla_moe"):
    return MOE.moe_init(key, cfg, dtype)
  if kind == "mlstm":
    f = 2 * cfg.d_model
  elif kind == "slstm":
    f = max(64, int(round(cfg.d_model * 4 / 3 / 64)) * 64)
  else:
    f = cfg.d_ff
  return L.mlp_init(key, cfg.d_model, f, _ffn_variant(cfg, kind), dtype)


def _mixer_init(key, cfg, kind, dtype):
  if kind in ("dense", "global", "local", "moe"):
    return {"attn": L.attn_init(key, cfg, dtype)}
  if kind == "mla_moe":
    return {"mla": MLA.mla_init(key, cfg, dtype)}
  if kind == "rg":
    return {"rg": RG.rg_init(key, cfg, dtype)}
  if kind == "mlstm":
    return {"mlstm": XL.mlstm_init(key, cfg, dtype)}
  if kind == "slstm":
    return {"slstm": XL.slstm_init(key, cfg, dtype)}
  raise ValueError(kind)


def _layer_init(key, cfg, kind) -> Params:
  dtype = _dtype(cfg)
  k1, k2 = jax.random.split(key)
  p = {
      "norm1": L.norm_init(cfg.d_model, cfg.norm),
      "norm2": L.norm_init(cfg.d_model, cfg.norm),
      "ffn": _ffn_init(k2, cfg, kind, dtype),
  }
  p.update(_mixer_init(k1, cfg, kind, dtype))
  return p


def _window(cfg, kind) -> int:
  return cfg.window_size if kind == "local" else 0


def _layer_apply_seq(p, x, positions, cfg, kind, *, collect_cache=False):
  """Returns (x, aux, cache_or_None)."""
  h = L.norm_apply(p["norm1"], x, cfg.norm)
  cache = None
  if kind in ("dense", "global", "local", "moe"):
    if collect_cache:
      mixed, (kc, vc) = L.attn_apply_seq(
          p["attn"], h, positions, cfg, window=_window(cfg, kind),
          return_kv=True)
      cache = {"k": kc, "v": vc}
    else:
      mixed = L.attn_apply_seq(
          p["attn"], h, positions, cfg, window=_window(cfg, kind))
  elif kind == "mla_moe":
    if collect_cache:
      mixed, cache = MLA.mla_apply_seq(
          p["mla"], h, positions, cfg, return_kv=True)
    else:
      mixed = MLA.mla_apply_seq(p["mla"], h, positions, cfg)
  elif kind == "rg":
    if collect_cache:
      mixed, cache = RG.rg_apply_seq(p["rg"], h, cfg, return_state=True)
    else:
      mixed = RG.rg_apply_seq(p["rg"], h, cfg)
  elif kind == "mlstm":
    if collect_cache:
      mixed, cache = XL.mlstm_apply_seq(p["mlstm"], h, cfg, return_state=True)
    else:
      mixed = XL.mlstm_apply_seq(p["mlstm"], h, cfg)
  elif kind == "slstm":
    if collect_cache:
      mixed, cache = XL.slstm_apply_seq(p["slstm"], h, cfg, return_state=True)
    else:
      mixed = XL.slstm_apply_seq(p["slstm"], h, cfg)
  else:
    raise ValueError(kind)
  x = x + mixed.astype(x.dtype)
  x = shard_activation(x, "residual")

  h2 = L.norm_apply(p["norm2"], x, cfg.norm)
  aux = jnp.zeros((), jnp.float32)
  if kind in ("moe", "mla_moe"):
    ff, aux = MOE.moe_apply(p["ffn"], h2, cfg)
  else:
    ff = L.mlp_apply(p["ffn"], h2, _ffn_variant(cfg, kind))
  x = x + ff.astype(x.dtype)
  x = shard_activation(x, "residual")
  return x, aux, cache


def _layer_apply_decode(p, x, cache, pos, cfg, kind):
  """x: (B, d). Returns (x, new_cache)."""
  h = L.norm_apply(p["norm1"], x, cfg.norm)
  if kind in ("dense", "global", "local", "moe"):
    mixed, cache = L.attn_apply_decode(
        p["attn"], h, cache, pos, cfg, window=_window(cfg, kind))
  elif kind == "mla_moe":
    mixed, cache = MLA.mla_apply_decode(p["mla"], h, cache, pos, cfg)
  elif kind == "rg":
    mixed, cache = RG.rg_apply_decode(p["rg"], h, cache, cfg)
  elif kind == "mlstm":
    mixed, cache = XL.mlstm_apply_decode(p["mlstm"], h, cache, cfg)
  elif kind == "slstm":
    mixed, cache = XL.slstm_apply_decode(p["slstm"], h, cache, cfg)
  else:
    raise ValueError(kind)
  x = x + mixed.astype(x.dtype)

  h2 = L.norm_apply(p["norm2"], x, cfg.norm)
  if kind in ("moe", "mla_moe"):
    ff, _ = MOE.moe_apply(p["ffn"], h2, cfg)
  else:
    ff = L.mlp_apply(p["ffn"], h2, _ffn_variant(cfg, kind))
  x = x + ff.astype(x.dtype)
  x = shard_activation(x, "residual_decode")
  return x, cache


def _layer_init_cache(cfg, kind, batch, max_len, dtype):
  if kind in ("dense", "global", "local", "moe"):
    win = _window(cfg, kind)
    length = min(max_len, win + 8) if win else max_len
    # window caches could be ring buffers; keep full length for simplicity
    return L.attn_init_cache(cfg, batch, max_len, dtype)
  if kind == "mla_moe":
    return MLA.mla_init_cache(cfg, batch, max_len, dtype)
  if kind == "rg":
    return RG.rg_init_state(cfg, batch, dtype)
  if kind == "mlstm":
    return XL.mlstm_init_state(cfg, batch)
  if kind == "slstm":
    return XL.slstm_init_state(cfg, batch)
  raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init / apply
# ---------------------------------------------------------------------------


def init_params(cfg, key) -> Params:
  dtype = _dtype(cfg)
  keys = jax.random.split(key, 8)
  params: Params = {}
  if cfg.frontend != "audio":
    params["embed"] = L.embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                   dtype)
  if cfg.num_codebooks:
    for i in range(cfg.num_codebooks):
      params[f"codebook_head_{i}"] = {
          "w": (jax.random.normal(jax.random.fold_in(keys[1], i),
                                  (cfg.d_model, cfg.vocab_size)) *
                (1.0 / math.sqrt(cfg.d_model))).astype(dtype)}
  elif not cfg.tie_embeddings:
    params["lm_head"] = {
        "w": (jax.random.normal(keys[2], (cfg.d_model, cfg.vocab_size)) *
              (1.0 / math.sqrt(cfg.d_model))).astype(dtype)}
  params["final_norm"] = L.norm_init(cfg.d_model, cfg.norm)

  for si, (cycle, reps) in enumerate(cfg.plan_segments()):
    seg: Params = {}
    for j, kind in enumerate(cycle):
      lkeys = jax.random.split(
          jax.random.fold_in(keys[3], si * 64 + j), reps)
      seg[f"l{j}_{kind}"] = jax.vmap(
          lambda k: _layer_init(k, cfg, kind))(lkeys)
    params[f"seg{si}"] = seg
  return params


def _head_weight(cfg, params):
  if cfg.tie_embeddings:
    return params["embed"]["table"].T
  return params["lm_head"]["w"]


def _embed_inputs(cfg, params, batch) -> tuple[Array, Array]:
  """Returns (x (B,S,d), positions (S,))."""
  if cfg.frontend == "audio":
    x = batch["embeds"].astype(_dtype(cfg))     # stub: precomputed frames
  elif cfg.frontend == "vision":
    tok = L.embed_apply(params["embed"], batch["tokens"],
                        scale=cfg.norm == "rmsnorm" and cfg.tie_embeddings)
    img = batch["image_embeds"].astype(tok.dtype)
    x = jnp.concatenate([img, tok], axis=1)
  else:
    x = L.embed_apply(params["embed"], batch["tokens"],
                      scale=cfg.tie_embeddings)
  positions = jnp.arange(x.shape[1])
  return x, positions


def _run_segments(cfg, params, x, positions, *, collect_caches=False):
  """Scan all segments. Returns (x, aux_total, caches|None)."""
  aux_total = jnp.zeros((), jnp.float32)
  caches: list[Any] = []

  for si, (cycle, reps) in enumerate(cfg.plan_segments()):
    seg_params = params[f"seg{si}"]

    def seg_body(carry, layer_params, cycle=cycle):
      x, aux = carry
      cache_out = {}
      for j, kind in enumerate(cycle):
        x, a, c = _layer_apply_seq(
            layer_params[f"l{j}_{kind}"], x, positions, cfg, kind,
            collect_cache=collect_caches)
        aux = aux + a
        if collect_caches:
          cache_out[f"l{j}_{kind}"] = c
      return (x, aux), cache_out if collect_caches else None

    if cfg.remat == "full":
      seg_body = jax.checkpoint(
          seg_body, policy=jax.checkpoint_policies.nothing_saveable,
          static_argnums=())
    elif cfg.remat == "dots":
      seg_body = jax.checkpoint(
          seg_body, policy=jax.checkpoint_policies.checkpoint_dots)

    (x, aux_total), seg_caches = lax.scan(
        seg_body, (x, aux_total), seg_params)
    caches.append(seg_caches)

  return x, aux_total, caches if collect_caches else None


def forward_train(cfg, params, batch) -> tuple[Array, Array]:
  """Per-token NLL (B, S_target) + aux loss scalar."""
  x, positions = _embed_inputs(cfg, params, batch)
  x, aux, _ = _run_segments(cfg, params, x, positions)
  x = L.norm_apply(params["final_norm"], x, cfg.norm)

  if cfg.num_codebooks:
    losses = []
    for i in range(cfg.num_codebooks):
      w = params[f"codebook_head_{i}"]["w"]
      losses.append(L.lm_loss_chunked(
          w, x, batch["targets"][..., i], chunk=cfg.xent_chunk,
          softcap=cfg.logit_softcap))
    return jnp.mean(jnp.stack(losses), axis=0), aux
  if cfg.frontend == "vision":
    x = x[:, -batch["tokens"].shape[1]:]        # loss on text region only
  w = _head_weight(cfg, params)
  loss = L.lm_loss_chunked(w, x, batch["targets"], chunk=cfg.xent_chunk,
                           softcap=cfg.logit_softcap)
  return loss, aux


def init_cache(cfg, batch: int, max_len: int) -> list[Any]:
  dtype = _dtype(cfg)
  caches = []
  for cycle, reps in cfg.plan_segments():
    seg = {}
    for j, kind in enumerate(cycle):
      one = _layer_init_cache(cfg, kind, batch, max_len, dtype)
      seg[f"l{j}_{kind}"] = jax.tree.map(
          lambda a: jnp.broadcast_to(a, (reps,) + a.shape), one)
    caches.append(seg)
  return caches


def forward_prefill(cfg, params, batch, max_len: int):
  """Prefill: returns (last-position logits (B, V), caches).

  Attention caches are written at positions [0, S); the returned cache
  tensors are padded to `max_len` so decode can continue in place.
  """
  x, positions = _embed_inputs(cfg, params, batch)
  s = x.shape[1]
  x, _, caches = _run_segments(cfg, params, x, positions,
                               collect_caches=True)
  x = L.norm_apply(params["final_norm"], x, cfg.norm)
  last = x[:, -1]
  if cfg.num_codebooks:
    logits = jnp.stack([
        L.lm_head_logits(params[f"codebook_head_{i}"]["w"], last,
                         cfg.logit_softcap)
        for i in range(cfg.num_codebooks)], axis=1)
  else:
    logits = L.lm_head_logits(_head_weight(cfg, params), last,
                              cfg.logit_softcap)

  def pad_cache(c):
    def pad_leaf(a, proto):
      if a is None:
        return proto
      if a.ndim >= 3 and a.shape[2] == s and proto.shape[2] == max_len:
        pad = [(0, 0)] * a.ndim
        pad[2] = (0, max_len - s)
        return jnp.pad(a, pad).astype(proto.dtype)
      return a.astype(proto.dtype)
    return pad_leaf

  protos = init_cache(cfg, x.shape[0], max_len)
  padded = []
  for got, proto in zip(caches, protos):
    padded.append(jax.tree.map(pad_cache(None), got, proto))
  return logits, padded


def forward_decode(cfg, params, caches, inputs, pos: Array):
  """One decode step.

  inputs: token ids (B,) — or for the audio frontend, a precomputed frame
  embedding (B, d).  pos: scalar int32 current position (cache fill level).
  Returns (logits (B, V) [or (B, K, V)], new caches).
  """
  if cfg.frontend == "audio":
    x = inputs.astype(_dtype(cfg))
  else:
    x = L.embed_apply(params["embed"], inputs, scale=cfg.tie_embeddings)
  x = shard_activation(x, "residual_decode")

  new_caches = []
  for si, (cycle, reps) in enumerate(cfg.plan_segments()):
    seg_params = params[f"seg{si}"]
    seg_cache = caches[si]

    # The stacked cache rides the scan CARRY with indexed in-place updates
    # (not xs->ys, which would allocate a second cache-sized buffer): the
    # donated input then aliases straight through to the output.
    def seg_body(carry, inp, cycle=cycle):
      x, cache_stacked = carry
      i, lp = inp
      new_slices = {}
      for j, kind in enumerate(cycle):
        key = f"l{j}_{kind}"
        lc = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            cache_stacked[key])
        x, c = _layer_apply_decode(lp[key], x, lc, pos, cfg, kind)
        new_slices[key] = c
      cache_stacked = jax.tree.map(
          lambda a, u: lax.dynamic_update_index_in_dim(
              a, u.astype(a.dtype), i, 0),
          cache_stacked, new_slices)
      return (x, cache_stacked), None

    reps_idx = jnp.arange(reps)
    (x, new_seg), _ = lax.scan(
        seg_body, (x, seg_cache), (reps_idx, seg_params))
    new_caches.append(new_seg)

  x = L.norm_apply(params["final_norm"], x, cfg.norm)
  if cfg.num_codebooks:
    logits = jnp.stack([
        L.lm_head_logits(params[f"codebook_head_{i}"]["w"], x,
                         cfg.logit_softcap)
        for i in range(cfg.num_codebooks)], axis=1)
  else:
    logits = L.lm_head_logits(_head_weight(cfg, params), x,
                              cfg.logit_softcap)
    logits = shard_activation(logits, "logits_decode")
  return logits, new_caches


def count_params(params) -> int:
  return sum(x.size for x in jax.tree.leaves(params))

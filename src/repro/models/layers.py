"""Shared model layers: norms, RoPE, MLPs, chunked flash attention, LM head.

Everything is einsum-based with explicit parameter pytrees (plain dicts) so
sharding specs attach by path.  Attention never materializes the full
(S x S) score matrix: queries are processed in ``q_chunk`` blocks with an
inner scan over ``kv_chunk`` blocks carrying running (max, denom, acc) —
flash-attention restated in pure JAX so XLA:TPU can keep blocks in VMEM.
Sliding-window layers scan only the window's kv blocks via dynamic slices,
making them O(S * W) (this is what qualifies gemma3/recurrentgemma local
layers for the 500k-token cell).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.specs import shard_activation

Array = jax.Array
Params = dict[str, Any]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(d: int, kind: str) -> Params:
  p = {"scale": jnp.ones((d,), jnp.float32)}
  if kind == "layernorm":
    p["bias"] = jnp.zeros((d,), jnp.float32)
  return p


def norm_apply(p: Params, x: Array, kind: str, eps: float = 1e-6) -> Array:
  xf = x.astype(jnp.float32)
  if kind == "layernorm":
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]
  else:
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(ms + eps) * p["scale"]
  return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
  """x: (..., S, H, D) or (..., H, D) w/ scalar positions; rotate pairs."""
  d = x.shape[-1]
  half = d // 2
  freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
  ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
  cos, sin = jnp.cos(ang), jnp.sin(ang)
  cos = cos[..., None, :]  # broadcast over heads
  sin = sin[..., None, :]
  x1, x2 = x[..., :half], x[..., half:]
  out = jnp.concatenate(
      [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
  return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, variant: str, dtype) -> Params:
  k1, k2, k3 = jax.random.split(key, 3)
  scale_in = 1.0 / math.sqrt(d)
  scale_out = 1.0 / math.sqrt(f)
  p = {
      "w_in": (jax.random.normal(k1, (d, f)) * scale_in).astype(dtype),
      "w_out": (jax.random.normal(k2, (f, d)) * scale_out).astype(dtype),
  }
  if variant in ("swiglu", "geglu"):
    p["w_gate"] = (jax.random.normal(k3, (d, f)) * scale_in).astype(dtype)
  return p


def mlp_apply(p: Params, x: Array, variant: str) -> Array:
  h = jnp.einsum("...d,df->...f", x, p["w_in"])
  if variant == "swiglu":
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    h = jax.nn.silu(g) * h
  elif variant == "geglu":
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    h = jax.nn.gelu(g, approximate=True) * h
  else:
    h = jax.nn.gelu(h, approximate=True)
  if h.ndim == 3:
    h = shard_activation(h, "ffn")
  return jnp.einsum("...f,fd->...d", h, p["w_out"])


# ---------------------------------------------------------------------------
# Chunked flash attention (GQA, causal, optional sliding window)
# ---------------------------------------------------------------------------


def _attend_block(q, k, v, mask, scale, softcap):
  """q: (B,cq,Hkv,G,D)  k/v: (B,ckv,Hkv,D)  mask: (cq,ckv) bool."""
  s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
  if softcap > 0.0:
    s = jnp.tanh(s / softcap) * softcap
  s = jnp.where(mask[None, None, None], s, _NEG_INF)
  m = jnp.max(s, axis=-1)                           # (B,Hkv,G,cq)
  p = jnp.exp(s - m[..., None])
  l = jnp.sum(p, axis=-1)
  o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v)
  return m, l, o


def _merge(m1, l1, o1, m2, l2, o2):
  m = jnp.maximum(m1, m2)
  a1 = jnp.exp(m1 - m)
  a2 = jnp.exp(m2 - m)
  l = l1 * a1 + l2 * a2
  o = o1 * a1[..., None].astype(o1.dtype) + o2 * a2[..., None].astype(o2.dtype)
  return m, l, o


def flash_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    softcap: float = 0.0,
    q_offset: int | Array = 0,
) -> Array:
  """Chunked attention. q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D) -> (B,Sq,H,D).

  `q_offset`: global position of q[0] relative to k[0] (prefill continuation
  / decode). With `window > 0` only kv blocks inside the window are visited
  (O(S*W)); otherwise all kv blocks are scanned with causal masking.
  """
  b, sq, h, d = q.shape
  _, skv, hkv, _ = k.shape
  dv = v.shape[-1]          # may differ from d (MLA: un-padded values)
  g = h // hkv
  scale = 1.0 / math.sqrt(d)
  q_chunk = min(q_chunk, sq)
  kv_chunk = min(kv_chunk, skv)
  while sq % q_chunk:
    q_chunk -= 1
  while skv % kv_chunk:
    kv_chunk -= 1
  nq, nkv = sq // q_chunk, skv // kv_chunk
  qg = q.reshape(b, sq, hkv, g, d)

  def one_q_block(qi, q_blk):
    """q_blk: (B,cq,Hkv,G,D); returns (B,cq,Hkv,G,D)."""
    q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

    m0 = jnp.full((b, hkv, g, q_chunk), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
    o0 = jnp.zeros((b, hkv, g, q_chunk, dv), v.dtype)

    if window > 0:
      # visit only blocks overlapping [q_lo - window + 1, q_hi]
      w_blocks = window // kv_chunk + 2
      first = (q_offset + qi * q_chunk - window) // kv_chunk

      def body(carry, j):
        m, l, o = carry
        blk = jnp.clip(first + j, 0, nkv - 1)
        k_blk = lax.dynamic_slice_in_dim(k, blk * kv_chunk, kv_chunk, 1)
        v_blk = lax.dynamic_slice_in_dim(v, blk * kv_chunk, kv_chunk, 1)
        kv_pos = blk * kv_chunk + jnp.arange(kv_chunk)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (
            kv_pos[None, :] > q_pos[:, None] - window) & (
            (first + j) >= 0)
        mb, lb, ob = _attend_block(q_blk, k_blk, v_blk, mask, scale, softcap)
        return _merge(m, l, o, mb, lb, ob), None

      (m, l, o), _ = lax.scan(body, (m0, l0, o0), jnp.arange(w_blocks))
    else:
      def body(carry, j):
        m, l, o = carry
        k_blk = lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, 1)
        v_blk = lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, 1)
        kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
        if causal:
          mask = kv_pos[None, :] <= q_pos[:, None]
        else:
          mask = jnp.ones((q_chunk, kv_chunk), bool)
        mb, lb, ob = _attend_block(q_blk, k_blk, v_blk, mask, scale, softcap)
        return _merge(m, l, o, mb, lb, ob), None

      (m, l, o), _ = lax.scan(body, (m0, l0, o0), jnp.arange(nkv))

    out = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
    return out.transpose(0, 3, 1, 2, 4)  # (B,cq,Hkv,G,D)

  if nq == 1:
    out = one_q_block(0, qg)
  else:
    qs = qg.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    out = lax.map(lambda args: one_q_block(args[0], args[1]),
                  (jnp.arange(nq), qs))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, dv)
  return out.reshape(b, sq, h, dv)


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, cache_len: Array, *,
    window: int = 0, softcap: float = 0.0) -> Array:
  """Single-token attention. q: (B,H,D); caches: (B,S,Hkv,D) -> (B,H,D)."""
  b, h, d = q.shape
  _, s, hkv, _ = k_cache.shape
  g = h // hkv
  scale = 1.0 / math.sqrt(d)
  qg = q.reshape(b, hkv, g, d)
  s_ = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32) * scale
  if softcap > 0.0:
    s_ = jnp.tanh(s_ / softcap) * softcap
  pos = jnp.arange(s)
  valid = pos < cache_len
  if window > 0:
    valid &= pos > cache_len - 1 - window
  s_ = jnp.where(valid[None, None, None], s_, _NEG_INF)
  p = jax.nn.softmax(s_, axis=-1)
  o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
  return o.reshape(b, h, d)


# ---------------------------------------------------------------------------
# GQA attention layer (params + train/prefill/decode)
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype) -> Params:
  d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
  k1, k2, k3, k4 = jax.random.split(key, 4)
  si = 1.0 / math.sqrt(d)
  so = 1.0 / math.sqrt(h * dh)
  return {
      "wq": (jax.random.normal(k1, (d, h, dh)) * si).astype(dtype),
      "wk": (jax.random.normal(k2, (d, hkv, dh)) * si).astype(dtype),
      "wv": (jax.random.normal(k3, (d, hkv, dh)) * si).astype(dtype),
      "wo": (jax.random.normal(k4, (h, dh, d)) * so).astype(dtype),
  }


def attn_apply_seq(
    p: Params, x: Array, positions: Array, cfg, *,
    window: int = 0, return_kv: bool = False):
  """Full-sequence attention (train / prefill). x: (B,S,d)."""
  q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
  k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
  v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
  q = shard_activation(rope(q, positions, cfg.rope_theta), "heads")
  k = shard_activation(rope(k, positions, cfg.rope_theta), "heads")
  o = flash_attention(
      q, k, v, causal=True, window=window,
      q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
  out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
  if return_kv:
    return out, (k, v)
  return out


def attn_apply_decode(
    p: Params, x: Array, cache: Params, pos: Array, cfg, *,
    window: int = 0):
  """One-token step. x: (B,d); cache: {k,v}: (B,S,Hkv,Dh)."""
  q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
  k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
  v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
  q = rope(q, pos, cfg.rope_theta)
  k = rope(k, pos, cfg.rope_theta)
  k_cache = lax.dynamic_update_slice_in_dim(
      cache["k"], k[:, None].astype(cache["k"].dtype), pos, 1)
  v_cache = lax.dynamic_update_slice_in_dim(
      cache["v"], v[:, None].astype(cache["v"].dtype), pos, 1)
  o = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
  out = jnp.einsum("bhk,hkd->bd", o, p["wo"])
  return out, {"k": k_cache, "v": v_cache}


def attn_init_cache(cfg, batch: int, max_len: int, dtype) -> Params:
  shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
  return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Embedding + chunked LM loss
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype) -> Params:
  return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed_apply(p: Params, tokens: Array, scale: bool = False) -> Array:
  out = jnp.take(p["table"], tokens, axis=0)
  if scale:
    out = out * math.sqrt(out.shape[-1])
  return out


def lm_head_logits(w: Array, x: Array, softcap: float = 0.0) -> Array:
  logits = jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)
  if softcap > 0.0:
    logits = jnp.tanh(logits / softcap) * softcap
  return logits


def lm_loss_chunked(
    w: Array, x: Array, targets: Array, *,
    chunk: int = 1024, softcap: float = 0.0) -> Array:
  """Per-token NLL (B,S) without materializing (B,S,V): scan over S chunks."""
  b, s, d = x.shape
  chunk = min(chunk, s)
  while s % chunk:          # largest divisor of s not exceeding `chunk`
    chunk -= 1
  n = s // chunk
  xs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
  ts = targets.reshape(b, n, chunk).transpose(1, 0, 2)

  def body(_, inp):
    x_c, t_c = inp
    logits = lm_head_logits(w, x_c, softcap)
    logits = shard_activation(logits, "logits")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
    return None, logz - gold

  _, losses = lax.scan(body, None, (xs, ts))
  return losses.transpose(1, 0, 2).reshape(b, s)

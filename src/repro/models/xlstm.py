"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential).

mLSTM training runs in the stabilized parallel form with the same q-block /
kv-block chunking skeleton as flash attention (decay-biased logits, running
max), so the (S x S) weight matrix never materializes; decode keeps the
(C, n, m) recurrent state: O(1) per token -> qualifies for the 500k cell.
sLSTM has a genuine hidden-to-hidden nonlinearity, so training scans
sequentially (``lax.scan``) — the honest cost of that block type.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.specs import shard_activation

Array = jax.Array
Params = dict[str, Any]

_NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg, dtype) -> Params:
  d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
  ks = jax.random.split(key, 7)
  si = 1.0 / math.sqrt(d)
  return {
      "w_q": (jax.random.normal(ks[0], (d, h, dh)) * si).astype(dtype),
      "w_k": (jax.random.normal(ks[1], (d, h, dh)) * si).astype(dtype),
      "w_v": (jax.random.normal(ks[2], (d, h, dh)) * si).astype(dtype),
      "w_i": (jax.random.normal(ks[3], (d, h)) * si).astype(jnp.float32),
      "w_f": (jax.random.normal(ks[4], (d, h)) * si).astype(jnp.float32),
      "b_f": jnp.full((h,), 3.0, jnp.float32),  # open forget gates at init
      "w_o": (jax.random.normal(ks[5], (d, h, dh)) * si).astype(dtype),
      "w_out": (jax.random.normal(ks[6], (h, dh, d)) *
                (1.0 / math.sqrt(h * dh))).astype(dtype),
  }


def mlstm_apply_seq(p: Params, x: Array, cfg, *, return_state: bool = False):
  """Stabilized parallel mLSTM. x: (B,S,d) -> (B,S,d).

  logits_{t,j} = (q_t . k_j)/sqrt(dh) + F_t - F_j + itilde_j  (j <= t),
  F_t = cumsum(log sigmoid(ftilde)); output normalized by
  max(|sum_j w|, exp(-m)) per the xLSTM stabilization.
  """
  b, s, d = x.shape
  h, dh = cfg.num_heads, cfg.head_dim
  q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"]) / math.sqrt(dh)
  k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"])
  v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"])
  q = shard_activation(q, "heads")
  i_t = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_i"])
  f_t = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_f"]) + p["b_f"]
  log_f = jax.nn.log_sigmoid(f_t)
  f_cum = jnp.cumsum(log_f, axis=1)                       # (B,S,H)

  qc = min(cfg.q_chunk, s)
  kc = min(cfg.kv_chunk, s)
  while s % qc:
    qc -= 1
  while s % kc:
    kc -= 1
  nq, nkv = s // qc, s // kc

  def one_q_block(qi, q_blk, fq_blk):
    # q_blk: (B,cq,H,dh); fq_blk: (B,cq,H)
    m0 = jnp.full((b, h, qc), _NEG, jnp.float32)
    num0 = jnp.zeros((b, h, qc, dh), jnp.float32)
    den0 = jnp.zeros((b, h, qc), jnp.float32)
    q_pos = qi * qc + jnp.arange(qc)

    def body(carry, j):
      m, num, den = carry
      k_blk = lax.dynamic_slice_in_dim(k, j * kc, kc, 1)
      v_blk = lax.dynamic_slice_in_dim(v, j * kc, kc, 1)
      fk_blk = lax.dynamic_slice_in_dim(f_cum, j * kc, kc, 1)
      ik_blk = lax.dynamic_slice_in_dim(i_t, j * kc, kc, 1)
      # mLSTM is *linear* in the q.k score; only gate decays are in the
      # exponent:  w_{t,j} = exp(F_t - F_j + itilde_j - m_t) * (q_t . k_j).
      # (§Perf iter 5 tried bf16 block tensors here: REFUTED on this
      # backend — XLA:CPU has no native bf16, so every cast materializes a
      # block-sized convert and traffic grew 30%.  Revisit on real TPU.)
      score = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(jnp.float32)
      decay = (fq_blk.transpose(0, 2, 1)[..., None]
               - fk_blk.transpose(0, 2, 1)[:, :, None, :]
               + ik_blk.transpose(0, 2, 1)[:, :, None, :])
      kv_pos = j * kc + jnp.arange(kc)
      mask = kv_pos[None, :] <= q_pos[:, None]
      decay = jnp.where(mask[None, None], decay, _NEG)
      m_new = jnp.maximum(m, jnp.max(decay, axis=-1))
      alpha = jnp.exp(m - m_new)
      w = jnp.exp(decay - m_new[..., None]) * score
      num = num * alpha[..., None] + jnp.einsum(
          "bhqk,bkhd->bhqd", w, v_blk.astype(jnp.float32))
      den = den * alpha + jnp.sum(w, axis=-1)
      return (m_new, num, den), None

    (m, num, den), _ = lax.scan(body, (m0, num0, den0), jnp.arange(nkv))
    norm = jnp.maximum(jnp.abs(den), jnp.exp(-m))
    out = num / norm[..., None]
    return out.transpose(0, 2, 1, 3)  # (B,cq,H,dh)

  qs = q.reshape(b, nq, qc, h, dh).transpose(1, 0, 2, 3, 4)
  fqs = f_cum.reshape(b, nq, qc, h).transpose(1, 0, 2, 3)
  if nq == 1:
    o = one_q_block(0, qs[0], fqs[0])
  else:
    o = lax.map(lambda a: one_q_block(a[0], a[1], a[2]),
                (jnp.arange(nq), qs, fqs))
    o = o.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
  o = o.reshape(b, s, h, dh)

  og = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["w_o"]))
  y = jnp.einsum("bshk,hkd->bsd", (og * o.astype(og.dtype)), p["w_out"])

  if return_state:
    # Recurrent state equivalent to having consumed the whole sequence.
    state = mlstm_init_state(cfg, b)
    state = _mlstm_state_from_seq(state, k, v, i_t, f_cum)
    return y, state
  return y


def _mlstm_state_from_seq(state, k, v, i_t, f_cum):
  """Fold a full sequence into (C, n, m) in one pass (for prefill)."""
  f_last = f_cum[:, -1][:, :, None]                      # (B,H,1)
  logw = (f_last - f_cum.transpose(0, 2, 1)
          + i_t.transpose(0, 2, 1))                      # (B,H,S)
  m = jnp.max(logw, axis=-1)                             # (B,H)
  w = jnp.exp(logw - m[..., None])
  c = jnp.einsum("bhs,bshk,bshv->bhkv", w,
                 k.astype(jnp.float32), v.astype(jnp.float32))
  n = jnp.einsum("bhs,bshk->bhk", w, k.astype(jnp.float32))
  return {"c": c, "n": n, "m": m}


def mlstm_init_state(cfg, batch: int) -> Params:
  h, dh = cfg.num_heads, cfg.head_dim
  return {
      "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
      "n": jnp.zeros((batch, h, dh), jnp.float32),
      "m": jnp.full((batch, h), _NEG, jnp.float32),
  }


def mlstm_apply_decode(p: Params, x: Array, state: Params, cfg):
  """One-token recurrent step. x: (B,d)."""
  h, dh = cfg.num_heads, cfg.head_dim
  q = jnp.einsum("bd,dhk->bhk", x, p["w_q"]).astype(jnp.float32) / math.sqrt(dh)
  k = jnp.einsum("bd,dhk->bhk", x, p["w_k"]).astype(jnp.float32)
  v = jnp.einsum("bd,dhk->bhk", x, p["w_v"]).astype(jnp.float32)
  i_t = jnp.einsum("bd,dh->bh", x.astype(jnp.float32), p["w_i"])
  f_t = jnp.einsum("bd,dh->bh", x.astype(jnp.float32), p["w_f"]) + p["b_f"]
  log_f = jax.nn.log_sigmoid(f_t)

  m_new = jnp.maximum(state["m"] + log_f, i_t)
  a = jnp.exp(state["m"] + log_f - m_new)
  bgt = jnp.exp(i_t - m_new)
  c = state["c"] * a[..., None, None] + bgt[..., None, None] * (
      k[..., :, None] * v[..., None, :])
  n = state["n"] * a[..., None] + bgt[..., None] * k
  c = shard_activation(c, "mlstm_state")
  num = jnp.einsum("bhk,bhkv->bhv", q, c)
  den = jnp.abs(jnp.einsum("bhk,bhk->bh", q, n))
  out = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
  og = jax.nn.sigmoid(jnp.einsum("bd,dhk->bhk", x, p["w_o"]))
  y = jnp.einsum("bhk,hkd->bd", og * out.astype(og.dtype), p["w_out"])
  return y, {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg, dtype) -> Params:
  d = cfg.d_model
  h, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
  ks = jax.random.split(key, 3)
  si = 1.0 / math.sqrt(d)
  sr = 1.0 / math.sqrt(dh)
  # 4 gates (i, f, z, o); recurrent weights block-diagonal per head.
  # Stored in the model dtype (bf16): the recurrence streams `r` from HBM
  # every timestep, so weight bytes — not flops — bound sLSTM throughput;
  # gate math still accumulates in f32 (hillclimb iter 2, EXPERIMENTS §Perf).
  return {
      "w": (jax.random.normal(ks[0], (d, 4, h, dh)) * si).astype(dtype),
      "r": (jax.random.normal(ks[1], (h, dh, 4, dh)) * sr).astype(dtype),
      "b": jnp.zeros((4, h, dh), jnp.float32),
      "w_out": (jax.random.normal(ks[2], (h, dh, d)) *
                (1.0 / math.sqrt(d))).astype(dtype),
  }


def slstm_init_state(cfg, batch: int) -> Params:
  h, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
  z = jnp.zeros((batch, h, dh), jnp.float32)
  return {"c": z, "n": z + 1e-6, "m": z - 10.0, "h": z}


def _slstm_cell(p: Params, xw: Array, state: Params):
  """xw: pre-computed input projections (B,4,H,dh)."""
  rec = jnp.einsum("bhk,hkgv->bghv", state["h"].astype(p["r"].dtype),
                   p["r"], preferred_element_type=jnp.float32)
  pre = xw + rec + p["b"]
  it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
  log_f = jax.nn.log_sigmoid(ft)
  m_new = jnp.maximum(state["m"] + log_f, it)
  a = jnp.exp(state["m"] + log_f - m_new)
  bgt = jnp.exp(it - m_new)
  c = state["c"] * a + bgt * jnp.tanh(zt)
  n = state["n"] * a + bgt
  hid = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
  return {"c": c, "n": n, "m": m_new, "h": hid}


_EPS_N = 1e-6


@jax.custom_vjp
def _slstm_scan(xw, r, bias):
  """sLSTM recurrence over time with a hand-written backward.

  xw: (S,B,4,H,dh) input projections; r: (H,dh,4,dh) recurrent weights;
  bias: (4,H,dh).  Returns (hs (S,B,H,dh), final (c,n,m,h)).

  Why custom (hillclimb §Perf, xlstm pair): under autodiff the per-step
  dL/dr contribution is a rank-4 outer product *and* (with batch sharded
  over data) a per-step cross-device all-reduce — ~100k collectives per
  train step.  Here the backward reverse-scan emits per-step gate
  cotangents (dpre) as ys and computes dL/dr as ONE einsum (one
  all-reduce) outside the loop.  The stabilizer m is gradient-transparent
  (h_t is exactly invariant to it — c and n scale identically), matching
  the xLSTM reference implementation.
  """
  hs, state, _ = _slstm_fwd_scan(xw, r, bias)
  return hs, state


def _gates(pre):
  return pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]


def _slstm_fwd_scan(xw, r, bias):
  b = xw.shape[1]
  h_, dh = r.shape[0], r.shape[1]
  zeros = jnp.zeros((b, h_, dh), jnp.float32)
  state0 = (zeros, zeros + _EPS_N, zeros - 10.0, zeros)  # c, n, m, h

  def step(state, xw_t):
    c, n, m, h = state
    rec = jnp.einsum("bhk,hkgv->bghv", h.astype(r.dtype), r,
                     preferred_element_type=jnp.float32)
    pre = xw_t + rec + bias
    i_p, f_p, z_p, o_p = _gates(pre)
    log_f = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(m + log_f, i_p)
    a = jnp.exp(m + log_f - m_new)
    bgt = jnp.exp(i_p - m_new)
    c_new = c * a + bgt * jnp.tanh(z_p)
    n_new = n * a + bgt
    h_new = jax.nn.sigmoid(o_p) * c_new / jnp.maximum(n_new, _EPS_N)
    return (c_new, n_new, m_new, h_new), (h_new, pre, a, c_new, n_new)

  state, ys = lax.scan(step, state0, xw)
  hs = ys[0]
  return hs, state, ys


def _slstm_scan_fwd(xw, r, bias):
  hs, state, ys = _slstm_fwd_scan(xw, r, bias)
  return (hs, state), (r, ys)


def _slstm_scan_bwd(saved, cotangents):
  r, (hs, pres, a_s, c_post, n_post) = saved
  d_hs, (d_c_fin, d_n_fin, _, d_h_fin) = cotangents
  r32 = r.astype(jnp.float32)

  def shift_prev(post, init_val):
    first = jnp.full_like(post[:1], init_val)
    return jnp.concatenate([first, post[:-1]], axis=0)

  c_prev = shift_prev(c_post, 0.0)
  n_prev = shift_prev(n_post, _EPS_N)
  h_prev = shift_prev(hs, 0.0)

  def step(carry, inp):
    dc, dn, dh_rec = carry
    d_h_out, pre, a, c_pm1, n_pm1, c_t, n_t = inp
    i_p, f_p, z_p, o_p = _gates(pre)
    sig_o = jax.nn.sigmoid(o_p)
    tanh_z = jnp.tanh(z_p)
    bgt = n_t - a * n_pm1                       # exact recurrence identity
    n_cl = jnp.maximum(n_t, _EPS_N)

    dh_total = d_h_out + dh_rec
    d_o_pre = dh_total * (c_t / n_cl) * sig_o * (1.0 - sig_o)
    dc_t = dh_total * sig_o / n_cl + dc
    dn_t = jnp.where(n_t > _EPS_N,
                     -dh_total * sig_o * c_t / (n_cl * n_cl), 0.0) + dn
    d_a = dc_t * c_pm1 + dn_t * n_pm1
    d_bgt = dc_t * tanh_z + dn_t
    d_z_pre = dc_t * bgt * (1.0 - tanh_z * tanh_z)
    d_f_pre = a * d_a * jax.nn.sigmoid(-f_p)    # d/dx log_sigmoid = sig(-x)
    d_i_pre = bgt * d_bgt
    dpre = jnp.stack([d_i_pre, d_f_pre, d_z_pre, d_o_pre], axis=1)
    dh_rec_next = jnp.einsum("bghv,hkgv->bhk", dpre, r32)
    return (dc_t * a, dn_t * a, dh_rec_next), dpre

  carry0 = (d_c_fin, d_n_fin, d_h_fin)
  _, dpres = lax.scan(
      step, carry0, (d_hs, pres, a_s, c_prev, n_prev, c_post, n_post),
      reverse=True)

  d_xw = dpres
  # ONE weight-gradient contraction (and hence one data-axis all-reduce)
  # for the whole sequence — the point of this custom backward.
  d_r = jnp.einsum("sbghv,sbhk->hkgv", dpres, h_prev).astype(r.dtype)
  d_bias = jnp.sum(dpres, axis=(0, 1))
  return d_xw, d_r, d_bias


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm_apply_seq(p: Params, x: Array, cfg, *, return_state: bool = False):
  """Sequential sLSTM over time with the custom low-collective backward."""
  xw = jnp.einsum("bsd,dghk->bsghk", x.astype(p["w"].dtype), p["w"],
                  preferred_element_type=jnp.float32)
  hs, (c, n, m, h) = _slstm_scan(
      xw.transpose(1, 0, 2, 3, 4), p["r"], p["b"])
  hs = hs.transpose(1, 0, 2, 3)                          # (B,S,H,dh)
  y = jnp.einsum("bshk,hkd->bsd", hs.astype(x.dtype), p["w_out"])
  if return_state:
    return y, {"c": c, "n": n, "m": m, "h": h}
  return y


def slstm_apply_decode(p: Params, x: Array, state: Params, cfg):
  xw = jnp.einsum("bd,dghk->bghk", x.astype(p["w"].dtype), p["w"],
                  preferred_element_type=jnp.float32)
  state = _slstm_cell(p, xw, state)
  y = jnp.einsum("bhk,hkd->bd", state["h"].astype(x.dtype), p["w_out"])
  return y, state

"""Multi-head Latent Attention (DeepSeek-V2) — compressed KV cache.

Train/prefill use the expanded form; decode uses the *absorbed* form: W_uk
is folded into the query so attention runs directly against the cached
latent c_kv (rank 512) + shared RoPE key (64), which is the whole point of
MLA (cache bytes ~ (r + rope) per token instead of 2*H*Dh).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import flash_attention, rope
from repro.sharding.specs import shard_activation

Array = jax.Array
Params = dict[str, Any]

_NEG_INF = -1e30


def mla_init(key, cfg, dtype) -> Params:
  d, h = cfg.d_model, cfg.num_heads
  r, nd, rd, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                   cfg.v_head_dim)
  ks = jax.random.split(key, 5)
  si = 1.0 / math.sqrt(d)
  sr = 1.0 / math.sqrt(r)
  return {
      "wq": (jax.random.normal(ks[0], (d, h, nd + rd)) * si).astype(dtype),
      "w_dkv": (jax.random.normal(ks[1], (d, r + rd)) * si).astype(dtype),
      "w_uk": (jax.random.normal(ks[2], (r, h, nd)) * sr).astype(dtype),
      "w_uv": (jax.random.normal(ks[3], (r, h, vd)) * sr).astype(dtype),
      "wo": (jax.random.normal(ks[4], (h, vd, d)) /
             math.sqrt(h * vd)).astype(dtype),
  }


def mla_apply_seq(p: Params, x: Array, positions: Array, cfg, *,
                  return_kv: bool = False):
  """Expanded MLA for train/prefill. x: (B,S,d)."""
  nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
  r = cfg.kv_lora_rank
  q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
  q_nope, q_rope = q[..., :nd], q[..., nd:]
  q_rope = rope(q_rope, positions, cfg.rope_theta)

  ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
  c_kv, k_rope = ckv_full[..., :r], ckv_full[..., r:]
  k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)  # (B,S,1,rd)

  k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
  v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])

  h = cfg.num_heads
  k_rope_b = jnp.broadcast_to(k_rope, k_rope.shape[:2] + (h, rd))
  q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
  k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
  q_full = shard_activation(q_full, "heads")
  k_full = shard_activation(k_full, "heads")

  # V stays at v_head_dim (128): padding it to the 192-wide qk dim cost
  # 50% extra attention-output traffic+flops (§Perf deepseek iter d5).
  o = flash_attention(q_full, k_full, v, causal=True,
                      q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
  out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
  if return_kv:
    return out, {"c_kv": c_kv, "k_rope": k_rope[..., 0, :]}
  return out


def mla_init_cache(cfg, batch: int, max_len: int, dtype) -> Params:
  return {
      "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
      "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
  }


def mla_apply_decode(p: Params, x: Array, cache: Params, pos: Array, cfg):
  """Absorbed-form decode. x: (B,d); cache latents (B,S,r),(B,S,rd)."""
  nd, rd, r, h = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.kv_lora_rank,
                  cfg.num_heads)
  scale = 1.0 / math.sqrt(nd + rd)
  q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
  q_nope, q_rope = q[..., :nd], q[..., nd:]
  q_rope = rope(q_rope, pos, cfg.rope_theta)

  ckv_full = jnp.einsum("bd,dr->br", x, p["w_dkv"])
  c_new, kr_new = ckv_full[..., :r], ckv_full[..., r:]
  kr_new = rope(kr_new[..., None, :], pos, cfg.rope_theta)[..., 0, :]
  c_cache = lax.dynamic_update_slice_in_dim(
      cache["c_kv"], c_new[:, None].astype(cache["c_kv"].dtype), pos, 1)
  kr_cache = lax.dynamic_update_slice_in_dim(
      cache["k_rope"], kr_new[:, None].astype(cache["k_rope"].dtype), pos, 1)

  # Absorb W_uk into q: q_lat (B,H,r) attends directly to latents.
  q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, p["w_uk"])
  s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, c_cache)
  s_rope = jnp.einsum("bhk,bsk->bhs", q_rope, kr_cache)
  s = (s_lat + s_rope).astype(jnp.float32) * scale
  spos = jnp.arange(c_cache.shape[1])
  s = jnp.where((spos < pos + 1)[None, None], s, _NEG_INF)
  pw = jax.nn.softmax(s, axis=-1)
  # Attend over latents, then decompress once: (B,H,r) @ W_uv.
  o_lat = jnp.einsum("bhs,bsr->bhr", pw.astype(c_cache.dtype), c_cache)
  o = jnp.einsum("bhr,rhk->bhk", o_lat, p["w_uv"])
  out = jnp.einsum("bhk,hkd->bd", o, p["wo"])
  return out, {"c_kv": c_cache, "k_rope": kr_cache}

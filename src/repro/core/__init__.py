"""Core: fast differentiable sorting and ranking (Blondel et al., ICML 2020).

O(n log n) soft sort/rank via projection onto the permutahedron, reduced to
isotonic optimization solved exactly by PAV, with O(n) exact Jacobian
products (no differentiation through solver iterates).
"""

from repro.core.isotonic import (
    isotonic_kl,
    isotonic_l2,
    set_default_impl,
)
from repro.core.losses import (
    hard_rank,
    soft_lts_loss,
    soft_spearman_loss,
    soft_topk_loss,
    soft_trimmed_token_loss,
    spearman_correlation,
    topk_accuracy,
)
from repro.core.operators import (
    eps_max,
    eps_min,
    soft_quantile,
    soft_rank,
    soft_rank_kl_direct,
    soft_sort,
    soft_topk_mask,
)
from repro.core.permutations import SortContext
from repro.core.projection import projection_permutahedron
from repro.plan import (
    ExecutionPlan,
    PlanRule,
    load_plan,
    set_active_plan,
    use_plan,
)

__all__ = [
    "SortContext",
    "ExecutionPlan",
    "PlanRule",
    "load_plan",
    "set_active_plan",
    "use_plan",
    "isotonic_kl",
    "isotonic_l2",
    "set_default_impl",
    "projection_permutahedron",
    "soft_sort",
    "soft_rank",
    "soft_rank_kl_direct",
    "soft_topk_mask",
    "soft_quantile",
    "eps_min",
    "eps_max",
    "soft_spearman_loss",
    "spearman_correlation",
    "hard_rank",
    "soft_topk_loss",
    "topk_accuracy",
    "soft_lts_loss",
    "soft_trimmed_token_loss",
]

"""Soft sorting and ranking operators (paper Eq. 5-6) and derived top-k.

Conventions follow the paper: the *descending* direction is primitive;
`rho = (n, n-1, ..., 1)`; rank 1 is assigned to the largest entry under the
descending direction.  All operators act on the last axis and accept
arbitrary leading batch dimensions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projection import projection_permutahedron

Array = jax.Array

_DIRECTIONS = ("ASCENDING", "DESCENDING")


def _rho(n: int, dtype) -> Array:
  return jnp.arange(n, 0, -1, dtype=dtype)


def soft_sort(
    values: Array,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    direction: str = "DESCENDING",
    impl: str | None = None,
) -> Array:
  """Soft sort s_{eps*Psi}(theta) = P_Psi(rho/eps, theta)  (paper Eq. 5).

  ``impl`` selects the isotonic backend ("auto" | "lax" | "pallas" |
  "minimax"); None defers to the dispatch default (see
  ``repro.kernels.dispatch``).
  """
  if direction not in _DIRECTIONS:
    raise ValueError(f"direction must be one of {_DIRECTIONS}")
  values = jnp.asarray(values)
  if direction == "ASCENDING":
    return -soft_sort(-values, regularization_strength, regularization,
                      impl=impl)
  eps = regularization_strength
  n = values.shape[-1]
  z = _rho(n, values.dtype) / eps
  z = jnp.broadcast_to(z, values.shape)
  return projection_permutahedron(z, values, regularization, impl)


def soft_rank(
    values: Array,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    direction: str = "DESCENDING",
    impl: str | None = None,
) -> Array:
  """Soft rank r_{eps*Psi}(theta) = P_Psi(-theta/eps, rho)  (paper Eq. 6).

  DESCENDING (paper default): rank 1 for the largest value.
  ASCENDING: rank 1 for the smallest value ( = descending rank of -theta ).
  """
  if direction not in _DIRECTIONS:
    raise ValueError(f"direction must be one of {_DIRECTIONS}")
  values = jnp.asarray(values)
  if direction == "ASCENDING":
    return soft_rank(-values, regularization_strength, regularization,
                     impl=impl)
  eps = regularization_strength
  n = values.shape[-1]
  w = _rho(n, values.dtype)
  return projection_permutahedron(-values / eps, w, regularization, impl)


def soft_rank_kl_direct(
    values: Array, regularization_strength: float = 1.0,
    impl: str | None = None) -> Array:
  """Appendix variant r~_E: KL projection directly onto P(rho) (not P(e^rho)).

  r~_{eps E}(theta) = exp(P_E(-theta/eps, log rho)).
  """
  values = jnp.asarray(values)
  eps = regularization_strength
  n = values.shape[-1]
  w = jnp.log(_rho(n, values.dtype))
  return jnp.exp(projection_permutahedron(-values / eps, w, "kl", impl))


def soft_topk_mask(
    values: Array,
    k: int,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    impl: str | None = None,
) -> Array:
  """Differentiable top-k indicator in [0, 1]^n summing to k.

  Projection of theta/eps onto P(w) with w = (1,...,1,0,...,0) (k ones): the
  vertices of that permutahedron are exactly the 0/1 indicators of
  k-subsets, so the projection is the canonical soft top-k selector built
  from the paper's machinery (cf. §6.1's O(n log k) remark).
  """
  values = jnp.asarray(values)
  eps = regularization_strength
  n = values.shape[-1]
  w = jnp.concatenate([
      jnp.ones((k,), values.dtype),
      jnp.zeros((n - k,), values.dtype),
  ])
  return projection_permutahedron(values / eps, w, regularization, impl)


def soft_quantile(
    values: Array,
    q: float,
    regularization_strength: float = 0.1,
    regularization: str = "l2",
    impl: str | None = None,
) -> Array:
  """Differentiable q-quantile via the soft sort (ascending)."""
  values = jnp.asarray(values)
  n = values.shape[-1]
  s = soft_sort(values, regularization_strength, regularization,
                direction="ASCENDING", impl=impl)
  idx = jnp.clip(jnp.asarray(round(q * (n - 1)), jnp.int32), 0, n - 1)
  return s[..., idx]


# ---------------------------------------------------------------------------
# Exact-regime thresholds (paper Lemma 3) -- used by tests and EXPERIMENTS.md
# to validate the asymptotic claims *exactly* rather than approximately.
# ---------------------------------------------------------------------------


def eps_min(s: Array, w: Array) -> Array:
  """Largest eps at which P_Psi(z/eps, w) equals the hard operator.

  `s` must be sorted descending (s = z_sigma(z)); `w` sorted descending.
  For eps <= eps_min the soft operator is exactly hard (Lemma 3).
  """
  ds = s[..., :-1] - s[..., 1:]
  dw = w[..., :-1] - w[..., 1:]
  return jnp.min(ds / dw, axis=-1)


def eps_max(s: Array, w: Array) -> Array:
  """Smallest eps beyond which the solution is the closed-form constant."""
  n = s.shape[-1]
  i, j = jnp.triu_indices(n, k=1)
  num = s[..., i] - s[..., j]
  den = w[..., i] - w[..., j]
  return jnp.max(num / den, axis=-1)

"""Soft sorting and ranking operators (paper Eq. 5-6) and derived top-k.

Conventions follow the paper: the *descending* direction is primitive;
`rho = (n, n-1, ..., 1)`; rank 1 is assigned to the largest entry under the
descending direction.  All operators act on the last axis and accept
arbitrary leading batch dimensions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.permutations import SortContext
from repro.core.projection import projection_permutahedron

Array = jax.Array

_DIRECTIONS = ("ASCENDING", "DESCENDING")


def _rho(n: int, dtype) -> Array:
  return jnp.arange(n, 0, -1, dtype=dtype)


def _ctx_perm(sort_context: SortContext | None, descending: bool):
  """(sigma, sigma^{-1}) of the context's values in the given direction.

  Tie order may differ from a fresh argsort of the transformed argument
  (operators negate/scale their input before projecting), which is
  harmless: equal values merge into one isotonic block either way.
  """
  if sort_context is None:
    return None
  _, sigma, sigma_inv = (sort_context.descending() if descending
                         else sort_context.ascending())
  return sigma, sigma_inv


def soft_sort(
    values: Array,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    direction: str = "DESCENDING",
    impl: str | None = None,
    plan=None,
    sort_context: SortContext | None = None,
) -> Array:
  """Soft sort: s_{eps*Psi}(theta) = P_Psi(rho/eps, theta) (paper Eq. 5).

  Parameters
  ----------
  values : Array, shape (..., n)
      Input scores; the operator acts on the last axis, arbitrary leading
      batch dimensions are supported.
  regularization_strength : float
      eps > 0. As eps -> 0 the output approaches the hard sort (exactly
      hard for eps <= eps_min, Lemma 3); as eps -> inf it collapses
      toward a constant vector (l2) / rescaling (kl).
  regularization : {"l2", "kl"}
      Psi. "l2" is the paper's quadratic Q; "kl" the entropic E
      (projection carried out in log space).
  direction : {"DESCENDING", "ASCENDING"}
      "DESCENDING" (paper primitive) returns values softly sorted from
      largest to smallest; "ASCENDING" is -soft_sort(-values).
  impl : {"auto", "lax", "scan", "pallas", "minimax"} or None
      Isotonic backend; None defers to the unified precedence chain
      (``repro.kernels.dispatch``). Pass explicitly under jit/grad.
  plan : repro.plan.ExecutionPlan or None
      Pin an execution plan for all of this call's dispatch decisions;
      rides the custom VJP as a static argument, so it survives jit.
  sort_context : SortContext or None
      A ``SortContext`` built on ``values``; supplies the argsort
      permutation so several operators over the same tensor share one
      sort (trace-local — see the class docstring for the jit caveat).

  Returns
  -------
  Array, shape (..., n)
      The soft-sorted vector(s).

  Notes
  -----
  Cost is O(n log n) per row — one descending sort plus a linear-time
  PAV isotonic solve (paper §5) — versus O(n^2) for All-pairs and
  O(T n^2) for OT/Sinkhorn relaxations. The backward pass is the exact
  O(n) segment-algebra VJP of Lemma 2, never unrolled solver iterates.
  The projection's z argument (rho/eps) is descending by construction,
  so the fused pipeline (``repro.core.projection``) skips that sort
  entirely via ``z_is_sorted``.
  """
  if direction not in _DIRECTIONS:
    raise ValueError(f"direction must be one of {_DIRECTIONS}")
  values = jnp.asarray(values)
  eps = regularization_strength
  n = values.shape[-1]
  descending = direction == "DESCENDING"
  # ASCENDING is -P(rho/eps, -theta): same sorted z, negated weights.
  w = values if descending else -values
  z = jnp.broadcast_to(_rho(n, values.dtype) / eps, values.shape)
  out = projection_permutahedron(
      z, w, regularization, impl, plan=plan, z_is_sorted=True,
      w_perm=_ctx_perm(sort_context, descending=descending))
  return out if descending else -out


def soft_rank(
    values: Array,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    direction: str = "DESCENDING",
    impl: str | None = None,
    plan=None,
    sort_context: SortContext | None = None,
) -> Array:
  """Soft rank: r_{eps*Psi}(theta) = P_Psi(-theta/eps, rho) (paper Eq. 6).

  Parameters
  ----------
  values : Array, shape (..., n)
      Input scores (last axis; arbitrary leading batch dimensions).
  regularization_strength : float
      eps > 0; eps -> 0 recovers the hard ranks exactly (Lemma 3),
      larger eps trades fidelity for smoother gradients.
  regularization : {"l2", "kl"}
      Psi: quadratic Q or entropic E (paper §3).
  direction : {"DESCENDING", "ASCENDING"}
      "DESCENDING" (paper default): rank 1 for the largest value.
      "ASCENDING": rank 1 for the smallest ( = descending rank of
      -theta ).
  impl : {"auto", "lax", "scan", "pallas", "minimax"} or None
      Isotonic backend; see ``repro.kernels.dispatch``. Pass explicitly
      under jit/grad.
  plan : repro.plan.ExecutionPlan or None
      Pin an execution plan for all of this call's dispatch decisions;
      rides the custom VJP as a static argument, so it survives jit.
  sort_context : SortContext or None
      A ``SortContext`` built on ``values``; supplies the argsort
      permutation so several operators over the same tensor share one
      sort (trace-local — see the class docstring for the jit caveat).

  Returns
  -------
  Array, shape (..., n)
      Soft ranks in [1, n]; differentiable everywhere in theta.

  Notes
  -----
  O(n log n) per row (sort + linear PAV, §5) with the exact O(n) VJP of
  Lemma 2 — the differentiability does not cost an O(n^2) Jacobian.
  The projection's weight rho is descending by construction, so the
  fused pipeline never sorts it (``w_is_sorted``).
  """
  if direction not in _DIRECTIONS:
    raise ValueError(f"direction must be one of {_DIRECTIONS}")
  values = jnp.asarray(values)
  eps = regularization_strength
  n = values.shape[-1]
  descending = direction == "DESCENDING"
  # DESCENDING projects -theta/eps; ASCENDING is the descending rank of
  # -theta, i.e. projects +theta/eps.  Sorting z descending is sorting
  # theta ascending (resp. descending), which a SortContext serves.
  z = (-values if descending else values) / eps
  w = _rho(n, values.dtype)
  return projection_permutahedron(
      z, w, regularization, impl, plan=plan, w_is_sorted=True,
      z_perm=_ctx_perm(sort_context, descending=not descending))


def soft_rank_kl_direct(
    values: Array, regularization_strength: float = 1.0,
    direction: str = "DESCENDING",
    impl: str | None = None,
    plan=None,
    sort_context: SortContext | None = None) -> Array:
  """Appendix variant r~_E: KL projection directly onto P(rho), not P(e^rho).

  r~_{eps E}(theta) = exp(P_E(-theta/eps, log rho)).

  Parameters
  ----------
  values : Array, shape (..., n)
      Input scores (last axis).
  regularization_strength : float
      eps > 0.
  direction : {"DESCENDING", "ASCENDING"}
      "DESCENDING" (paper default): rank 1 for the largest value;
      "ASCENDING" is the descending variant of -theta.
  impl : {"auto", "lax", "scan", "pallas", "minimax"} or None
      Isotonic backend (``repro.kernels.dispatch``).
  plan : repro.plan.ExecutionPlan or None
      Pin an execution plan for all of this call's dispatch decisions.
  sort_context : SortContext or None
      A ``SortContext`` built on ``values`` (shares the argsort with
      other operators over the same tensor; trace-local under jit).

  Returns
  -------
  Array, shape (..., n)
      Strictly positive soft ranks (the exp of a log-space projection).

  Notes
  -----
  Same O(n log n) forward / O(n) backward as ``soft_rank``; only the
  target polytope differs (paper appendix discussion of r~_E).  The
  weight log(rho) is descending by construction (log is monotone), so
  the fused pipeline never sorts it.
  """
  if direction not in _DIRECTIONS:
    raise ValueError(f"direction must be one of {_DIRECTIONS}")
  values = jnp.asarray(values)
  eps = regularization_strength
  n = values.shape[-1]
  descending = direction == "DESCENDING"
  z = (-values if descending else values) / eps
  w = jnp.log(_rho(n, values.dtype))
  return jnp.exp(projection_permutahedron(
      z, w, "kl", impl, plan=plan, w_is_sorted=True,
      z_perm=_ctx_perm(sort_context, descending=not descending)))


def soft_topk_mask(
    values: Array,
    k: int,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    impl: str | None = None,
    plan=None,
    sort_context: SortContext | None = None,
) -> Array:
  """Differentiable top-k indicator in [0, 1]^n summing to k.

  Projection of theta/eps onto P(w) with w = (1,...,1,0,...,0) (k ones):
  the vertices of that permutahedron are exactly the 0/1 indicators of
  k-subsets, so the projection is the canonical soft top-k selector
  built from the paper's machinery (cf. §6.1's O(n log k) remark).

  Parameters
  ----------
  values : Array, shape (..., n)
      Selection scores (last axis).
  k : int
      Number of entries softly selected, 1 <= k <= n.
  regularization_strength : float
      eps > 0; small eps approaches the hard 0/1 top-k mask.
  regularization : {"l2", "kl"}
      Psi for the projection.
  impl : {"auto", "lax", "scan", "pallas", "minimax"} or None
      Isotonic backend (``repro.kernels.dispatch``).
  plan : repro.plan.ExecutionPlan or None
      Pin an execution plan for all of this call's dispatch decisions.

  Returns
  -------
  Array, shape (..., n)
      Mask in [0, 1]^n with sum k (exactly, by the projection's
      marginals); gradients flow to every entry, unlike hard top-k.

  Notes
  -----
  O(n log n) per row via the generic reduction (a specialized
  O(n log k) variant is possible, §6.1, but the generic path is what
  the MoE router benchmarks exercise — see
  ``repro.kernels.ops.soft_topk_gates`` for the fused kernel).
  """
  values = jnp.asarray(values)
  eps = regularization_strength
  n = values.shape[-1]
  # The k-ones mask is descending by construction: never sorted.
  w = jnp.concatenate([
      jnp.ones((k,), values.dtype),
      jnp.zeros((n - k,), values.dtype),
  ])
  return projection_permutahedron(
      values / eps, w, regularization, impl, plan=plan, w_is_sorted=True,
      z_perm=_ctx_perm(sort_context, descending=True))


def soft_quantile(
    values: Array,
    q: float,
    regularization_strength: float = 0.1,
    regularization: str = "l2",
    impl: str | None = None,
    plan=None,
    sort_context: SortContext | None = None,
) -> Array:
  """Differentiable q-quantile via the soft sort (ascending).

  Parameters
  ----------
  values : Array, shape (..., n)
      Samples (last axis).
  q : float
      Quantile in [0, 1]; the index round(q * (n-1)) of the ascending
      soft sort is returned (q=0.5 is a soft median).
  regularization_strength : float
      eps > 0 for the underlying soft sort (Eq. 5).
  regularization : {"l2", "kl"}
      Psi for the projection.
  impl : {"auto", "lax", "scan", "pallas", "minimax"} or None
      Isotonic backend (``repro.kernels.dispatch``).
  plan : repro.plan.ExecutionPlan or None
      Pin an execution plan for all of this call's dispatch decisions.
  sort_context : SortContext or None
      A ``SortContext`` built on ``values``: the underlying ascending
      soft sort reuses the caller's argsort instead of re-sorting.

  Returns
  -------
  Array, shape (...)
      The soft q-quantile per batch row (one scalar per row).

  Notes
  -----
  O(n log n) per row — inherited from ``soft_sort``; gradients spread
  over neighboring order statistics instead of the single hard sample.
  """
  values = jnp.asarray(values)
  n = values.shape[-1]
  s = soft_sort(values, regularization_strength, regularization,
                direction="ASCENDING", impl=impl, plan=plan,
                sort_context=sort_context)
  idx = jnp.clip(jnp.asarray(round(q * (n - 1)), jnp.int32), 0, n - 1)
  return s[..., idx]


# ---------------------------------------------------------------------------
# Exact-regime thresholds (paper Lemma 3) -- used by tests and EXPERIMENTS.md
# to validate the asymptotic claims *exactly* rather than approximately.
# ---------------------------------------------------------------------------


def eps_min(s: Array, w: Array) -> Array:
  """Largest eps at which P_Psi(z/eps, w) equals the hard operator.

  Parameters
  ----------
  s : Array, shape (..., n)
      Sorted-descending inputs, s = z_sigma(z).
  w : Array, shape (..., n)
      Sorted-descending target weights.

  Returns
  -------
  Array, shape (...)
      eps_min = min_i (s_i - s_{i+1}) / (w_i - w_{i+1}); for
      eps <= eps_min the soft operator is *exactly* the hard one
      (paper Lemma 3) — used by tests to validate asymptotics exactly.

  Notes
  -----
  O(n) per row (one pass over adjacent differences).
  """
  ds = s[..., :-1] - s[..., 1:]
  dw = w[..., :-1] - w[..., 1:]
  return jnp.min(ds / dw, axis=-1)


def eps_max(s: Array, w: Array) -> Array:
  """Smallest eps beyond which the solution is the closed-form constant.

  Parameters
  ----------
  s : Array, shape (..., n)
      Sorted-descending inputs.
  w : Array, shape (..., n)
      Sorted-descending target weights.

  Returns
  -------
  Array, shape (...)
      eps_max = max_{i<j} (s_i - s_j) / (w_i - w_j) (paper Lemma 3's
      other endpoint): beyond it every PAV block has merged and the
      projection is the fully-pooled closed form.

  Notes
  -----
  O(n^2) per row (all pairs) — a diagnostic for tests/analysis, not a
  production path.
  """
  n = s.shape[-1]
  i, j = jnp.triu_indices(n, k=1)
  num = s[..., i] - s[..., j]
  den = w[..., i] - w[..., j]
  return jnp.max(num / den, axis=-1)

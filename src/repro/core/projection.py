"""Projections onto the permutahedron (paper §4-§5, Prop. 3).

  P_Psi(z, w) = z - v_Psi(z_sigma(z), sort_desc(w))_{sigma^{-1}(z)}

`P(w)` is permutation-invariant in `w`, so `w` need not be sorted by the
caller.  Two registered pipelines compute it (dispatch registry keys
``("projection", regularization, path)``, selected by
``repro.kernels.dispatch.resolve_projection`` through the unified chain —
explicit ``path=`` > env ``REPRO_PROJECTION`` > execution plan; every
built-in plan resolves to ``"fused"``):

``"fused"`` (default)
    The whole pipeline is ONE ``jax.custom_vjp``: packed single-key
    integer sorts (``repro.core.permutations.argsort_descending_fast`` /
    ``invert_permutation_fast`` — the XLA integer-sort fast path, ~4x
    faster than comparator argsorts at n=1024), an explicitly-computed
    inverse permutation so the un-permute is a *gather* instead of the
    ``apply_inverse_permutation`` scatter, and a backward pass that reuses
    the residuals saved by the forward (sigma, sigma^{-1}, the solver's
    segment structure) — gather -> segmented scan -> gather, with no
    re-sort and no scatter.  Static ``z_is_sorted`` / ``w_is_sorted``
    flags skip sorts the caller guarantees (every built-in operator
    passes a by-construction-sorted argument on one side), and
    precomputed ``z_perm`` / ``w_perm`` permutations (from
    ``repro.core.permutations.SortContext``) let multi-operator callers
    pay for one argsort.  Unbatched *concrete* weights hit a small
    process-level sorted-``w`` cache, so eager eps sweeps never re-sort
    the same weight vector.

``"composed"``
    The reference chain of four differentiable primitives — descending
    sorts, isotonic solve, inverse-permutation scatter — kept reachable
    (``REPRO_PROJECTION=composed``) for differential testing of the fused
    path; its backward is whatever JAX derives by composition.

Batched-first in both paths: `z` may carry arbitrary leading batch
dimensions, there is ONE isotonic dispatch per call and no per-row Python
loop or vmap anywhere.  When `w` is unbatched (shape (n,)) it is sorted
exactly once and broadcast into the solver; its gradient still accumulates
correctly over the batch.
"""

from __future__ import annotations

import functools
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.isotonic import isotonic_kl, isotonic_l2
from repro.core.permutations import (
    apply_inverse_permutation,
    argsort_descending,
    argsort_descending_fast,
    inverse_permutation,
    invert_permutation_fast,
    sort_descending,
)
from repro.kernels import dispatch as _dispatch
from repro.kernels import segment_vjp as _svjp
from repro.obs import metrics as _metrics

Array = jax.Array

_REGS = ("l2", "kl")
_HALF_DTYPES = (jnp.bfloat16, jnp.float16)


# ---------------------------------------------------------------------------
# Composed reference pipeline (the pre-fusion implementation, unchanged).
# ---------------------------------------------------------------------------


def _composed_projection(regularization: str, z: Array, w: Array,
                         impl: str | None, plan=None, *,
                         z_is_sorted: bool = False,
                         w_is_sorted: bool = False, z_perm=None,
                         w_perm=None) -> Array:
  """z: (..., n); w: (n,) or broadcastable to z.shape.

  The reference path deliberately ignores the sortedness hints and
  re-derives everything through composed differentiable primitives —
  that is exactly what the fused path is differentially tested against.
  """
  del z_is_sorted, w_is_sorted, z_perm, w_perm
  if w.ndim == 1:
    # Unbatched weights: one sort, shared across every row of the batch.
    w_sorted, _ = sort_descending(w)
  else:
    w_sorted, _ = sort_descending(jnp.broadcast_to(w, z.shape))
  s, sigma = sort_descending(z)
  if regularization == "l2":
    v = isotonic_l2(s - w_sorted, impl, plan)
  else:
    v = isotonic_kl(s, w_sorted, impl, plan)
  # out = z - v_{sigma^{-1}}, i.e. out[sigma_k] = z[sigma_k] - v[k].
  return z - apply_inverse_permutation(v, sigma)


# ---------------------------------------------------------------------------
# Sorted-weight cache for concrete unbatched weights (eager fast path).
# ---------------------------------------------------------------------------

_W_CACHE_CAP = 64
_w_sorted_cache: OrderedDict[tuple, tuple] = OrderedDict()


def _sorted_w_unbatched(ws: Array) -> tuple[Array, Array, Array]:
  """(w sorted desc, tau, tau^{-1}) for an unbatched weight row.

  Concrete (non-tracer) weights are sorted once per distinct vector in a
  small bounded process cache — an eager eps sweep re-projecting onto the
  same permutahedron pays for exactly one weight sort.  Tracers (under
  jit the weights are abstract) go through the packed fast sort.
  """
  if isinstance(ws, jax.core.Tracer):
    w_sorted, tau = argsort_descending_fast(ws)
    return w_sorted, tau, invert_permutation_fast(tau)
  host = np.asarray(ws)
  key = (host.shape, str(host.dtype),
         hashlib.sha1(host.tobytes()).hexdigest())
  hit = key in _w_sorted_cache
  _metrics.counter_inc("sort_reuse_hit" if hit else "sort_reuse_miss",
                       source="w_cache")
  if hit:
    _w_sorted_cache.move_to_end(key)
  else:
    tau = np.argsort(-host, kind="stable").astype(np.int32)
    inv = np.argsort(tau, kind="stable").astype(np.int32)
    while len(_w_sorted_cache) >= _W_CACHE_CAP:
      _w_sorted_cache.popitem(last=False)
    _w_sorted_cache[key] = (host[tau], tau, inv)
  w_sorted, tau, inv = _w_sorted_cache[key]
  return jnp.asarray(w_sorted), jnp.asarray(tau), jnp.asarray(inv)


# ---------------------------------------------------------------------------
# Fused pipeline: one custom VJP around sort + solve + gather.
# ---------------------------------------------------------------------------


def _fused_forward(regularization, impl, plan, z_is_sorted, w_is_sorted,
                   z, w, z_perm, w_perm):
  """Shared primal: returns (out, residuals).

  This function is staged *inside* the custom_vjp, where the packed u64
  sort fast path miscompiles (see ``_fused_entry``), so the permutation
  fallbacks below use the safe comparator sorts.  Dispatch callers never
  hit them: ``_fused_entry`` precomputes ``z_perm`` / ``w_perm`` with the
  fast path in the surrounding trace context.
  """
  n = z.shape[-1]
  zs = lax.stop_gradient(z)
  if z_is_sorted:
    s, sigma, sigma_inv = zs, None, None
  elif z_perm is not None:
    sigma, sigma_inv = z_perm
    s = jnp.take_along_axis(zs, sigma, axis=-1)
  else:
    sigma = argsort_descending(zs)
    s = jnp.take_along_axis(zs, sigma, axis=-1)
    sigma_inv = inverse_permutation(sigma)

  ws = lax.stop_gradient(w)
  if ws.ndim > 1 and ws.shape != z.shape:
    ws = jnp.broadcast_to(ws, z.shape)
  tau_inv = None
  if w_is_sorted:
    w_sorted = ws
  elif w_perm is not None:
    tau, tau_inv = w_perm
    w_sorted = jnp.take_along_axis(ws, tau, axis=-1)
  else:
    tau = argsort_descending(ws)
    w_sorted = jnp.take_along_axis(ws, tau, axis=-1)
    tau_inv = inverse_permutation(tau)

  if regularization == "l2":
    y = s - w_sorted                       # broadcasts unbatched w_sorted
    v = _dispatch.dispatch("isotonic", "l2", impl, y, plan=plan)
    w_b = None
  else:
    w_b = jnp.broadcast_to(w_sorted, s.shape)
    v = _dispatch.dispatch("isotonic", "kl", impl, s, w_b, plan=plan)

  vd = lax.stop_gradient(v)
  starts = _svjp.block_starts(vd.reshape(-1, n)).reshape(v.shape)
  start_idx, end_idx = _svjp.start_end_indices(starts.reshape(-1, n))
  start_idx = start_idx.reshape(v.shape)
  end_idx = end_idx.reshape(v.shape)

  out = z - (v if sigma_inv is None else
             jnp.take_along_axis(v, sigma_inv, axis=-1))
  res = (sigma, sigma_inv, tau_inv, starts, start_idx, end_idx,
         s if regularization == "kl" else None, w_b, lax.stop_gradient(w))
  return out, res


def _unbroadcast(g: Array, shape: tuple[int, ...]) -> Array:
  """Sum a full-batch cotangent down to a broadcast-origin shape."""
  if g.shape == tuple(shape):
    return g
  extra = g.ndim - len(shape)
  if extra:
    g = g.sum(axis=tuple(range(extra)))
  axes = tuple(i for i, (a, b) in enumerate(zip(g.shape, shape))
               if b == 1 and a != 1)
  if axes:
    g = g.sum(axis=axes, keepdims=True)
  return g.reshape(shape)


def _perm_cotangent(perm):
  """Symbolic-zero (float0) cotangents for integer permutation inputs."""
  return jax.tree_util.tree_map(
      lambda a: np.zeros(np.shape(a), jax.dtypes.float0), perm)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _fused_projection(regularization, impl, plan, z_is_sorted, w_is_sorted,
                      z, w, z_perm, w_perm):
  return _fused_forward(regularization, impl, plan, z_is_sorted,
                        w_is_sorted, z, w, z_perm, w_perm)[0]


def _fused_fwd(regularization, impl, plan, z_is_sorted, w_is_sorted,
               z, w, z_perm, w_perm):
  out, res = _fused_forward(regularization, impl, plan, z_is_sorted,
                            w_is_sorted, z, w, z_perm, w_perm)
  return out, res + (z_perm, w_perm)


def _fused_bwd(regularization, impl, plan, z_is_sorted, w_is_sorted, res, g):
  """Whole-pipeline VJP from saved residuals: gather -> segmented
  reduction (Lemma 2, dispatched backward table) -> gather.  No re-sort,
  no scatter."""
  del impl, z_is_sorted
  (sigma, sigma_inv, tau_inv, starts, start_idx, end_idx, s, w_b, w_orig,
   z_perm, w_perm) = res

  # d out / d v is -I composed with the sigma^{-1} gather: permute the
  # cotangent into sorted order.
  g_v = -(g if sigma is None else jnp.take_along_axis(g, sigma, axis=-1))
  if regularization == "l2":
    g_y = _dispatch.dispatch_backward("projection", "l2", None,
                                      g_v, starts, start_idx, end_idx,
                                      plan=plan)
    g_s, g_ws = g_y, -g_y
  else:
    g_s, g_ws = _dispatch.dispatch_backward("projection", "kl", None,
                                            s, w_b, g_v, starts,
                                            start_idx, end_idx, plan=plan)

  # z cotangent: identity term plus the solve term mapped back through
  # sigma^{-1} (a gather — sigma^{-1} is already a residual).
  g_z = g + (g_s if sigma_inv is None else
             jnp.take_along_axis(g_s, sigma_inv, axis=-1))

  # w cotangent: back from sorted order via tau^{-1} (gather), then
  # un-broadcast (sum) onto the original weight shape.
  if w_orig.ndim == 1:
    g_w = _unbroadcast(g_ws, w_orig.shape)
    if tau_inv is not None:
      g_w = jnp.take_along_axis(g_w, tau_inv, axis=-1)
  else:
    if tau_inv is not None:
      g_ws = jnp.take_along_axis(g_ws, tau_inv, axis=-1)
    g_w = _unbroadcast(g_ws, w_orig.shape)
  return g_z, g_w, _perm_cotangent(z_perm), _perm_cotangent(w_perm)


_fused_projection.defvjp(_fused_fwd, _fused_bwd)


def _fused_entry(regularization: str, z: Array, w: Array, impl: str | None,
                 plan=None, *, z_is_sorted: bool = False,
                 w_is_sorted: bool = False, z_perm=None,
                 w_perm=None) -> Array:
  """Precompute the sort permutations OUTSIDE the custom_vjp, then project.

  The packed u64 argsort (``argsort_descending_fast``) must not be staged
  inside a custom_vjp body: when the custom_vjp sub-jaxpr is lowered with
  global x64 off, the size-changing u32(..., 2) -> u64 bitcast is
  re-canonicalized to a shape-preserving u32 -> u32 no-op, and the single
  packed sort silently splits into two *independent* word sorts — the
  sorted values (high word) still come out right, but the permutation
  payload (low word) degenerates to identity.  Plain jit and eager lower
  the bitcast correctly.  The sorts are nondifferentiable residuals
  (``stop_gradient``) in any case, so they run here, in the surrounding
  trace context, and enter the custom_vjp as ``z_perm`` / ``w_perm``
  (tests/test_projection_fused.py::test_fused_matches_eager_under_jit is
  the regression guard).
  """
  z = jnp.asarray(z)
  if not z_is_sorted and z_perm is None:
    _, sigma = argsort_descending_fast(lax.stop_gradient(z))
    z_perm = (sigma, invert_permutation_fast(sigma))
  if not w_is_sorted and w_perm is None:
    ws = lax.stop_gradient(jnp.asarray(w, z.dtype))
    if ws.ndim > 1 and ws.shape != z.shape:
      ws = jnp.broadcast_to(ws, z.shape)
    if ws.ndim == 1:
      _, tau, tau_inv = _sorted_w_unbatched(ws)
    else:
      _, tau = argsort_descending_fast(ws)
      tau_inv = invert_permutation_fast(tau)
    w_perm = (tau, tau_inv)
  return _fused_projection(regularization, impl, plan, bool(z_is_sorted),
                           bool(w_is_sorted), z, w, z_perm, w_perm)


for _reg in _REGS:
  _dispatch.register("projection", _reg, "fused")(
      functools.partial(_fused_entry, _reg))
  _dispatch.register("projection", _reg, "composed")(
      functools.partial(_composed_projection, _reg))


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------


def projection_permutahedron(
    z: Array, w: Array, regularization: str = "l2",
    impl: str | None = None, *, path: str | None = None, plan=None,
    z_is_sorted: bool = False, w_is_sorted: bool = False,
    z_perm=None, w_perm=None) -> Array:
  """Project `z` onto the permutahedron generated by `w` (paper §4).

  Computes P_Psi(z, w) = z - v_Psi(z_sigma(z), sort_desc(w))_{sigma^{-1}}
  (Prop. 3): one descending sort, one isotonic solve, one un-permute.

  Parameters
  ----------
  z : Array, shape (..., n)
      Point(s) to project (last axis; arbitrary leading batch dims).
  w : Array, shape (n,) or broadcastable to z.shape
      Permutahedron generator. P(w) is permutation-invariant in w, so w
      need not be sorted. An unbatched (n,) w is sorted once and
      broadcast into the solver (no per-row re-sort); its gradient
      still accumulates correctly through the broadcast.
  regularization : {"l2", "kl"}
      "l2": Euclidean projection onto P(w). "kl": the paper's log-KL
      projection of e^z onto P(e^w), returned in log space (P_E).
  impl : {"auto", "lax", "scan", "pallas", "minimax"} or None
      Isotonic backend (``repro.kernels.dispatch``); pass explicitly
      under jit/grad (see ``isotonic_l2`` for why).
  path : {"auto", "fused", "composed"} or None
      Pipeline selection; None defers to env ``REPRO_PROJECTION`` then
      the execution-plan chain (plans resolve to ``"fused"``).
  plan : repro.plan.ExecutionPlan or None
      Pin an execution plan for every decision this call makes (forward
      backend, backward backend, projection path).  Rides the fused
      custom VJP as a static argument, so — unlike ``use_plan`` — it
      survives jit and governs the lazily-traced backward too.
  z_is_sorted, w_is_sorted : bool
      Caller guarantees the argument is already descending along the
      last axis — the fused path skips that sort entirely.  (The
      composed reference path ignores the hints and always re-sorts.)
  z_perm, w_perm : (sigma, sigma^{-1}) int32 pairs or None
      Precomputed descending-argsort permutations for the respective
      argument (e.g. from ``repro.core.permutations.SortContext``) —
      the fused path replaces its packed sorts with two gathers.

  Returns
  -------
  Array, shape broadcast(z, w)
      The projection, same shape as the broadcast inputs.

  Notes
  -----
  O(n log n) per row — the sort dominates; the PAV solve is O(n) after
  sorting (§5) versus O(n^2) for all-pairs relaxations. The fused
  default carries a whole-pipeline custom VJP (residuals: sigma,
  sigma^{-1}, solver segment structure) whose backward is
  gather -> segmented scan -> gather — exact (Lemma 2), O(n), no
  re-sort, no scatter, never differentiation through solver iterates.
  """
  if regularization not in _REGS:
    raise ValueError(f"regularization must be one of {_REGS}")
  z = jnp.asarray(z)
  w = jnp.asarray(w, z.dtype)
  dtype = z.dtype
  if dtype in _HALF_DTYPES:
    # Promote before the pipeline (not just the solve): the fused path's
    # packed integer sort keys assume f32, so the whole projection runs
    # promoted and only the result is demoted.
    out = _dispatch.dispatch_projection(
        z.astype(jnp.float32), w.astype(jnp.float32), regularization, impl,
        path, plan=plan, z_is_sorted=z_is_sorted, w_is_sorted=w_is_sorted,
        z_perm=z_perm, w_perm=w_perm)
    return out.astype(dtype)
  return _dispatch.dispatch_projection(
      z, w, regularization, impl, path, plan=plan, z_is_sorted=z_is_sorted,
      w_is_sorted=w_is_sorted, z_perm=z_perm, w_perm=w_perm)

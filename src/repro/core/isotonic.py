"""Isotonic optimization via Pool-Adjacent-Violators (paper §5).

Solves, for a vector of length n,

  v_Q(s, w) = argmin_{v_1 >= ... >= v_n} 1/2 ||v - (s - w)||^2            (Q)
  v_E(s, w) = argmin_{v_1 >= ... >= v_n} <e^{s-v}, 1> + <e^w, v>          (E)

exactly, in O(n) after inputs are sorted, with the analytic block solutions

  gamma_Q(B) = mean_{i in B} (s_i - w_i)          (Eq. 7)
  gamma_E(B) = LSE(s_B) - LSE(w_B)                (Eq. 8)

This module is batched-first: the public operators accept arbitrary leading
batch dimensions and make exactly one dispatch call per forward pass
(``repro.kernels.dispatch``), which routes the flattened (rows, n) batch to
a registered backend — ``"lax"`` (reference ``lax.fori_loop`` stack machine,
natively batched), ``"scan"`` (log-depth divide-and-conquer PAV),
``"pallas"`` (tiled TPU kernel), or ``"minimax"`` (O(n^2) closed form for
small n / SPMD).  Backend choice follows the unified precedence chain
(explicit ``impl=`` > ``REPRO_BACKEND`` > execution plan — see
``repro.plan``); an :class:`~repro.plan.ExecutionPlan` can be pinned
per-call via ``plan=`` (it rides the custom_vjp as a static argument, so
it survives jit, unlike trace-time context managers).  The dtype contract
(bf16/f16 promoted to f32 for the solve, demoted on return) is enforced
centrally in dispatch — uniformly for every backend.

The backward pass is exact and O(n) for every forward backend (Lemma 2):
the Jacobian is block-diagonal with rank-1 blocks, recovered from runs of
equal values in the forward output, so the VJP is a couple of batched
segment reductions and never differentiates through solver iterates.  Those
reductions are themselves dispatched — ``dispatch_backward`` routes to a
registered backward backend (``"segscan"`` segmented prefix scans by
default, ``"scatter"`` segment_sum as the reference formulation; see
``repro.kernels.segment_vjp``) with its own named-scope attribution and
metrics.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Public, batched, differentiable operators.
# ---------------------------------------------------------------------------


def _dispatch(regularization: str, impl: str | None, plan,
              *args: Array) -> Array:
  from repro.kernels import dispatch as _d  # lazy: keep core import light
  return _d.dispatch("isotonic", regularization, impl, *args, plan=plan)


def _dispatch_bwd(regularization: str, plan, *args: Array):
  from repro.kernels import dispatch as _d  # lazy: keep core import light
  return _d.dispatch_backward("isotonic", regularization, None, *args,
                              plan=plan)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def isotonic_l2(y: Array, impl: str | None = None, plan=None) -> Array:
  """Isotonic regression: argmin ||v - y||^2, v non-increasing (last axis).

  ``impl`` / ``plan`` must be passed EXPLICITLY by callers that need a
  specific backend under jit/grad: custom_vjp fwd rules are traced lazily
  (after any trace-time context manager has exited), so ``use_impl`` /
  ``use_plan`` only affect eager/top-level calls.
  """
  return _dispatch("l2", impl, plan, y)


def _isotonic_l2_fwd(y, impl, plan):
  v = _dispatch("l2", impl, plan, y)
  return v, v


def _isotonic_l2_bwd(impl, plan, v, g):
  # Lemma 2 (Q): dv/dy is block-diagonal with blocks 11^T/|B| (symmetric).
  return (_dispatch_bwd("l2", plan, v, g),)


isotonic_l2.defvjp(_isotonic_l2_fwd, _isotonic_l2_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def isotonic_kl(s: Array, w: Array, impl: str | None = None,
                plan=None) -> Array:
  """Entropic-regularization isotonic optimization (paper Eq. 8), last axis."""
  return _isotonic_kl_impl(s, w, impl, plan)


def _isotonic_kl_impl(s: Array, w: Array, impl: str | None, plan) -> Array:
  w = jnp.broadcast_to(w, s.shape)
  return _dispatch("kl", impl, plan, s, w)


def _isotonic_kl_fwd(s, w, impl, plan):
  v = _isotonic_kl_impl(s, w, impl, plan)
  return v, (s, w, v)


def _isotonic_kl_bwd(impl, plan, res, g):
  s, w, v = res
  w_b = jnp.broadcast_to(w, s.shape)

  # Lemma 2 (E): B_j = 1 (x) softmax(s_B); transpose-multiply:
  #   grad_s = softmax(s_B) * sum(g_B);  grad_w = -softmax(w_B) * sum(g_B).
  grad_s, grad_w = _dispatch_bwd("kl", plan, s, w_b, v, g)
  # Un-broadcast w gradient if w was unbatched.
  if w.shape != s.shape:
    grad_w = jnp.sum(
        grad_w.reshape((-1,) + w.shape), axis=0).reshape(w.shape)
  return grad_s, grad_w


isotonic_kl.defvjp(_isotonic_kl_fwd, _isotonic_kl_bwd)


# ---------------------------------------------------------------------------
# Backend selection: thin aliases over the dispatch registry (kept for
# backward compatibility; see repro.kernels.dispatch for the registry).
# ---------------------------------------------------------------------------


def set_default_impl(impl: str) -> None:
  """Set the process-default backend (one of repro.kernels.dispatch.BACKENDS)."""
  from repro.kernels import dispatch as _d
  _d.set_default_backend(impl)


@contextlib.contextmanager
def use_impl(impl: str):
  """Temporarily select the isotonic solver backend (trace-time)."""
  from repro.kernels import dispatch as _d
  with _d.use_backend(impl):
    yield

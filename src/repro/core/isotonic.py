"""Isotonic optimization via Pool-Adjacent-Violators (paper §5).

Solves, for a vector of length n,

  v_Q(s, w) = argmin_{v_1 >= ... >= v_n} 1/2 ||v - (s - w)||^2            (Q)
  v_E(s, w) = argmin_{v_1 >= ... >= v_n} <e^{s-v}, 1> + <e^w, v>          (E)

exactly, in O(n) after inputs are sorted, with the analytic block solutions

  gamma_Q(B) = mean_{i in B} (s_i - w_i)          (Eq. 7)
  gamma_E(B) = LSE(s_B) - LSE(w_B)                (Eq. 8)

This module is batched-first: the public operators accept arbitrary leading
batch dimensions and make exactly one dispatch call per forward pass
(``repro.kernels.dispatch``), which routes the flattened (rows, n) batch to
a registered backend — ``"lax"`` (reference ``lax.fori_loop`` stack machine,
natively batched), ``"pallas"`` (tiled TPU kernel), or ``"minimax"`` (O(n^2)
closed form for small n / SPMD) — with ``"auto"`` resolving by platform and
shape.  All backends share this module's exact O(n) backward pass (Lemma 2):
the Jacobian is block-diagonal with rank-1 blocks, recovered from runs of
equal values in the forward output, so the VJP is two batched segment
reductions and never differentiates through solver iterates.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

Array = jax.Array

_INT = jnp.int32


# ---------------------------------------------------------------------------
# Block recovery + batched segment reductions shared by all backward passes.
# ---------------------------------------------------------------------------


def _block_ids(v: Array) -> Array:
  """Per-row segment ids from runs of equal values, v: (B, n) -> (B, n)."""
  starts = jnp.concatenate(
      [jnp.ones_like(v[:, :1], bool), v[:, 1:] != v[:, :-1]], axis=-1)
  return jnp.cumsum(starts.astype(_INT), axis=-1) - 1


def _flat_ids(bid: Array) -> Array:
  """Offset per-row block ids into one global id space (rows never mix)."""
  b, n = bid.shape
  return (bid + jnp.arange(b, dtype=_INT)[:, None] * n).reshape(-1)


def _segment_sum_bcast(g: Array, bid: Array) -> Array:
  """Within-block sum broadcast back to positions; g, bid: (B, n)."""
  b, n = g.shape
  gid = _flat_ids(bid)
  s = jax.ops.segment_sum(g.reshape(-1), gid, num_segments=b * n,
                          indices_are_sorted=True)
  return s[gid].reshape(b, n)


def _segment_mean_bcast(g: Array, bid: Array) -> Array:
  b, n = g.shape
  gid = _flat_ids(bid)
  gsum = jax.ops.segment_sum(g.reshape(-1), gid, num_segments=b * n,
                             indices_are_sorted=True)
  cnt = jax.ops.segment_sum(jnp.ones((b * n,), g.dtype), gid,
                            num_segments=b * n, indices_are_sorted=True)
  return (gsum / jnp.maximum(cnt, 1))[gid].reshape(b, n)


def _segment_softmax(x: Array, bid: Array) -> Array:
  """softmax within each block (exact, stable); x, bid: (B, n)."""
  b, n = x.shape
  gid = _flat_ids(bid)
  smax = jax.ops.segment_max(x.reshape(-1), gid, num_segments=b * n,
                             indices_are_sorted=True)
  ex = jnp.exp(x.reshape(-1) - smax[gid])
  denom = jax.ops.segment_sum(ex, gid, num_segments=b * n,
                              indices_are_sorted=True)
  return (ex / denom[gid]).reshape(b, n)


# ---------------------------------------------------------------------------
# Public, batched, differentiable operators.
# ---------------------------------------------------------------------------


def _dispatch(regularization: str, impl: str | None, *args: Array) -> Array:
  from repro.kernels import dispatch as _d  # lazy: keep core import light
  return _d.dispatch("isotonic", regularization, impl, *args)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def isotonic_l2(y: Array, impl: str | None = None) -> Array:
  """Isotonic regression: argmin ||v - y||^2, v non-increasing (last axis).

  ``impl`` must be passed EXPLICITLY by callers that need a specific backend
  under jit/grad: custom_vjp fwd rules are traced lazily (after any
  trace-time context manager has exited), so ``use_impl`` only affects
  eager/top-level calls.
  """
  return _isotonic_l2_impl(y, impl)


def _isotonic_l2_impl(y: Array, impl: str | None = None) -> Array:
  dtype = y.dtype
  y32 = y.astype(jnp.float32) if dtype in (jnp.bfloat16, jnp.float16) else y
  return _dispatch("l2", impl, y32).astype(dtype)


def _isotonic_l2_fwd(y, impl):
  v = _isotonic_l2_impl(y, impl)
  return v, v


def _isotonic_l2_bwd(impl, v, g):
  # Lemma 2 (Q): dv/dy is block-diagonal with blocks 11^T/|B| (symmetric).
  n = v.shape[-1]
  v2, g2 = v.reshape(-1, n), g.reshape(-1, n)
  out = _segment_mean_bcast(g2, _block_ids(v2))
  return (out.reshape(v.shape),)


isotonic_l2.defvjp(_isotonic_l2_fwd, _isotonic_l2_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def isotonic_kl(s: Array, w: Array, impl: str | None = None) -> Array:
  """Entropic-regularization isotonic optimization (paper Eq. 8), last axis."""
  return _isotonic_kl_impl(s, w, impl)


def _isotonic_kl_impl(s: Array, w: Array, impl: str | None = None) -> Array:
  dtype = s.dtype
  if dtype in (jnp.bfloat16, jnp.float16):
    s = s.astype(jnp.float32)
    w = w.astype(jnp.float32)
  w = jnp.broadcast_to(w, s.shape)
  return _dispatch("kl", impl, s, w).astype(dtype)


def _isotonic_kl_fwd(s, w, impl):
  v = _isotonic_kl_impl(s, w, impl)
  return v, (s, w, v)


def _isotonic_kl_bwd(impl, res, g):
  s, w, v = res
  w_b = jnp.broadcast_to(w, s.shape)

  # Lemma 2 (E): B_j = 1 (x) softmax(s_B); transpose-multiply:
  #   grad_s = softmax(s_B) * sum(g_B);  grad_w = -softmax(w_B) * sum(g_B).
  n = s.shape[-1]
  flat = lambda a: a.reshape(-1, n)
  bid = _block_ids(flat(v))
  gs = _segment_sum_bcast(flat(g), bid)
  grad_s = (_segment_softmax(flat(s), bid) * gs).reshape(s.shape)
  grad_w = (-_segment_softmax(flat(w_b), bid) * gs).reshape(s.shape)
  # Un-broadcast w gradient if w was unbatched.
  if w.shape != s.shape:
    grad_w = jnp.sum(
        grad_w.reshape((-1,) + w.shape), axis=0).reshape(w.shape)
  return grad_s, grad_w


isotonic_kl.defvjp(_isotonic_kl_fwd, _isotonic_kl_bwd)


# ---------------------------------------------------------------------------
# Backend selection: thin aliases over the dispatch registry (kept for
# backward compatibility; see repro.kernels.dispatch for the registry).
# ---------------------------------------------------------------------------


def set_default_impl(impl: str) -> None:
  """Set the process-default backend ("auto" | "lax" | "pallas" | "minimax")."""
  from repro.kernels import dispatch as _d
  _d.set_default_backend(impl)


@contextlib.contextmanager
def use_impl(impl: str):
  """Temporarily select the isotonic solver backend (trace-time)."""
  from repro.kernels import dispatch as _d
  with _d.use_backend(impl):
    yield

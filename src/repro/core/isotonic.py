"""Isotonic optimization via Pool-Adjacent-Violators (paper §5).

Solves, for a vector of length n,

  v_Q(s, w) = argmin_{v_1 >= ... >= v_n} 1/2 ||v - (s - w)||^2            (Q)
  v_E(s, w) = argmin_{v_1 >= ... >= v_n} <e^{s-v}, 1> + <e^w, v>          (E)

exactly, in O(n) after inputs are sorted, with the analytic block solutions

  gamma_Q(B) = mean_{i in B} (s_i - w_i)          (Eq. 7)
  gamma_E(B) = LSE(s_B) - LSE(w_B)                (Eq. 8)

The forward pass is a sequential stack machine implemented with
``lax.fori_loop``/``lax.while_loop`` so it is jittable, vmappable and runs
on any backend.  A Pallas TPU kernel (``repro.kernels.pav``) provides the
tiled batched fast path; both share this module's exact O(n) backward pass
(Lemma 2): the Jacobian is block-diagonal with rank-1 blocks, recovered from
runs of equal values in the forward output, so the VJP is two segment
reductions and never differentiates through solver iterates.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_INT = jnp.int32


def _expand_blocks(starts: Array, top: Array, block_vals: Array, n: int) -> Array:
  """Expand per-block values to per-position values.

  ``starts[:top+1]`` are increasing block start indices; positions are mapped
  to their block with a searchsorted on the (sentinel-padded) starts.
  """
  idx = jnp.arange(n, dtype=_INT)
  starts_pad = jnp.where(idx <= top, starts, n)
  bid = jnp.searchsorted(starts_pad, idx, side="right") - 1
  return block_vals[bid]


# ---------------------------------------------------------------------------
# Quadratic regularization (classic isotonic regression).
# ---------------------------------------------------------------------------


def _pav_l2_1d(y: Array) -> Array:
  """PAV for min ||v - y||^2 s.t. v non-increasing. y: (n,) float."""
  n = y.shape[0]
  sums = jnp.zeros(n, y.dtype)
  cnts = jnp.zeros(n, y.dtype)
  starts = jnp.zeros(n, _INT)

  def push(i, state):
    sums, cnts, starts, top = state
    cur = (y[i], jnp.ones((), y.dtype), jnp.asarray(i, _INT), top)

    def violated(c):
      cs, cc, _, t = c
      # value[top] <= current value  (cross-multiplied; counts > 0)
      return (t >= 0) & (sums[t] * cc <= cs * cnts[t])

    def merge(c):
      cs, cc, _, t = c
      return (cs + sums[t], cc + cnts[t], starts[t], t - 1)

    cs, cc, cstart, top = lax.while_loop(violated, merge, cur)
    top = top + 1
    return (
        sums.at[top].set(cs),
        cnts.at[top].set(cc),
        starts.at[top].set(cstart),
        top,
    )

  sums, cnts, starts, top = lax.fori_loop(
      0, n, push, (sums, cnts, starts, jnp.asarray(-1, _INT)))
  block_vals = sums / jnp.maximum(cnts, 1)
  return _expand_blocks(starts, top, block_vals, n)


# ---------------------------------------------------------------------------
# Entropic (KL) regularization.
# ---------------------------------------------------------------------------


def _pav_kl_1d(s: Array, w: Array) -> Array:
  """PAV for the E objective; returns v with v_i = LSE(s_B) - LSE(w_B)."""
  n = s.shape[0]
  lse_s = jnp.zeros(n, s.dtype)
  lse_w = jnp.zeros(n, s.dtype)
  starts = jnp.zeros(n, _INT)

  def push(i, state):
    lse_s_a, lse_w_a, starts, top = state
    cur = (s[i], w[i], jnp.asarray(i, _INT), top)

    def violated(c):
      cs, cw, _, t = c
      return (t >= 0) & (lse_s_a[t] - lse_w_a[t] <= cs - cw)

    def merge(c):
      cs, cw, _, t = c
      return (jnp.logaddexp(cs, lse_s_a[t]), jnp.logaddexp(cw, lse_w_a[t]),
              starts[t], t - 1)

    cs, cw, cstart, top = lax.while_loop(violated, merge, cur)
    top = top + 1
    return (
        lse_s_a.at[top].set(cs),
        lse_w_a.at[top].set(cw),
        starts.at[top].set(cstart),
        top,
    )

  lse_s, lse_w, starts, top = lax.fori_loop(
      0, n, push, (lse_s, lse_w, starts, jnp.asarray(-1, _INT)))
  return _expand_blocks(starts, top, lse_s - lse_w, n)


# ---------------------------------------------------------------------------
# Block recovery + segment reductions shared by all backward passes.
# ---------------------------------------------------------------------------


def _block_ids(v: Array) -> Array:
  """Segment ids from runs of equal values in the (non-increasing) solution."""
  n = v.shape[0]
  first = jnp.ones((1,), bool)
  starts = jnp.concatenate([first, v[1:] != v[:-1]])
  return jnp.cumsum(starts.astype(_INT)) - 1


def _segment_mean_bcast(g: Array, bid: Array) -> Array:
  n = g.shape[0]
  gsum = jax.ops.segment_sum(g, bid, num_segments=n)
  cnt = jax.ops.segment_sum(jnp.ones_like(g), bid, num_segments=n)
  return (gsum / jnp.maximum(cnt, 1))[bid]


def _segment_softmax(x: Array, bid: Array) -> Array:
  """softmax within each segment (exact, stable)."""
  n = x.shape[0]
  smax = jax.ops.segment_max(x, bid, num_segments=n)
  ex = jnp.exp(x - smax[bid])
  denom = jax.ops.segment_sum(ex, bid, num_segments=n)
  return ex / denom[bid]


def _segment_sum_bcast(g: Array, bid: Array) -> Array:
  n = g.shape[0]
  return jax.ops.segment_sum(g, bid, num_segments=n)[bid]


# ---------------------------------------------------------------------------
# Public, batched, differentiable operators.
# ---------------------------------------------------------------------------


def _batched(fn, *args):
  """Apply a 1-D function over the last axis of arbitrarily-batched inputs."""
  shape = args[0].shape
  n = shape[-1]
  flat = [a.reshape(-1, n) for a in args]
  out = jax.vmap(fn)(*flat)
  return out.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def isotonic_l2(y: Array, impl: str | None = None) -> Array:
  """Isotonic regression: argmin ||v - y||^2, v non-increasing (last axis).

  ``impl`` must be passed EXPLICITLY by callers that need a specific solver
  under jit/grad: custom_vjp fwd rules are traced lazily (after any
  trace-time context manager has exited), so ``use_impl`` only affects
  eager/top-level calls.
  """
  return _isotonic_l2_impl(y, impl)


def _isotonic_l2_impl(y: Array, impl: str | None = None) -> Array:
  impl = impl or _DEFAULT_IMPL["value"]
  dtype = y.dtype
  y32 = y.astype(jnp.float32) if dtype in (jnp.bfloat16, jnp.float16) else y
  if impl == "pallas":
    from repro.kernels import ops as _kops  # lazy: avoid circular import
    v = _kops.pav_l2(y32.reshape(-1, y32.shape[-1])).reshape(y32.shape)
  elif impl == "minimax":
    # O(n^2) closed form with zero data-dependent control flow: the right
    # trade on TPU for small n (MoE routers) and under SPMD, where a
    # vmapped while_loop would all-reduce its continuation predicate every
    # iteration (DESIGN.md §3).
    from repro.kernels.ref import pav_l2_ref
    v = pav_l2_ref(y32)
  else:
    v = _batched(_pav_l2_1d, y32)
  return v.astype(dtype)


def _isotonic_l2_fwd(y, impl):
  v = _isotonic_l2_impl(y, impl)
  return v, v


def _isotonic_l2_bwd(impl, v, g):
  # Lemma 2 (Q): dv/dy is block-diagonal with blocks 11^T/|B| (symmetric).
  def bwd1(v1, g1):
    bid = _block_ids(v1)
    return _segment_mean_bcast(g1, bid)

  n = v.shape[-1]
  out = jax.vmap(bwd1)(v.reshape(-1, n), g.reshape(-1, n)).reshape(v.shape)
  return (out,)


isotonic_l2.defvjp(_isotonic_l2_fwd, _isotonic_l2_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def isotonic_kl(s: Array, w: Array, impl: str | None = None) -> Array:
  """Entropic-regularization isotonic optimization (paper Eq. 8), last axis."""
  return _isotonic_kl_impl(s, w, impl)


def _isotonic_kl_impl(s: Array, w: Array, impl: str | None = None) -> Array:
  impl = impl or _DEFAULT_IMPL["value"]
  dtype = s.dtype
  if dtype in (jnp.bfloat16, jnp.float16):
    s = s.astype(jnp.float32)
    w = w.astype(jnp.float32)
  w = jnp.broadcast_to(w, s.shape)
  if impl == "pallas":
    from repro.kernels import ops as _kops
    n = s.shape[-1]
    v = _kops.pav_kl(s.reshape(-1, n), w.reshape(-1, n)).reshape(s.shape)
  elif impl == "minimax":
    from repro.kernels.ref import pav_kl_ref
    v = pav_kl_ref(s, w)
  else:
    v = _batched(_pav_kl_1d, s, w)
  return v.astype(dtype)


def _isotonic_kl_fwd(s, w, impl):
  v = _isotonic_kl_impl(s, w, impl)
  return v, (s, w, v)


def _isotonic_kl_bwd(impl, res, g):
  s, w, v = res
  w_b = jnp.broadcast_to(w, s.shape)

  # Lemma 2 (E): B_j = 1 (x) softmax(s_B); transpose-multiply:
  #   grad_s = softmax(s_B) * sum(g_B);  grad_w = -softmax(w_B) * sum(g_B).
  def bwd1(s1, w1, v1, g1):
    bid = _block_ids(v1)
    gs = _segment_sum_bcast(g1, bid)
    grad_s = _segment_softmax(s1, bid) * gs
    grad_w = -_segment_softmax(w1, bid) * gs
    return grad_s, grad_w

  n = s.shape[-1]
  flat = lambda a: a.reshape(-1, n)
  grad_s, grad_w = jax.vmap(bwd1)(flat(s), flat(w_b), flat(v), flat(g))
  grad_s = grad_s.reshape(s.shape)
  grad_w = grad_w.reshape(s.shape)
  # Un-broadcast w gradient if w was unbatched.
  if w.shape != s.shape:
    grad_w = jnp.sum(
        grad_w.reshape((-1,) + w.shape), axis=0).reshape(w.shape)
  return grad_s, grad_w


isotonic_kl.defvjp(_isotonic_kl_fwd, _isotonic_kl_bwd)


# Default implementation selector ("lax" everywhere; "pallas" opts the batched
# forward into the TPU kernel; "minimax" is the O(n^2) vectorized closed form
# for small n — identical semantics, shared backward).
_DEFAULT_IMPL = {"value": "lax"}

_IMPLS = ("lax", "pallas", "minimax")


def set_default_impl(impl: str) -> None:
  assert impl in _IMPLS, impl
  _DEFAULT_IMPL["value"] = impl


@contextlib.contextmanager
def use_impl(impl: str):
  """Temporarily select the isotonic solver implementation (trace-time)."""
  assert impl in _IMPLS, impl
  prev = _DEFAULT_IMPL["value"]
  _DEFAULT_IMPL["value"] = impl
  try:
    yield
  finally:
    _DEFAULT_IMPL["value"] = prev

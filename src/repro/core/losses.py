"""Losses built on the soft operators (paper §6 applications).

- soft Spearman's rank-correlation loss (label ranking, §6.3)
- soft top-k classification loss (§6.1)
- soft least-trimmed-squares (robust regression, §6.4), also used by the
  trainer to trim outlier *token* losses at LM-pretraining scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.operators import soft_rank, soft_sort
from repro.core.permutations import SortContext

Array = jax.Array


# ---------------------------------------------------------------------------
# Spearman (§6.3)
# ---------------------------------------------------------------------------


def soft_spearman_loss(
    theta: Array,
    target_ranks: Array,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    direction: str = "ASCENDING",
    plan=None,
    sort_context: SortContext | None = None,
) -> Array:
  """1/2 ||target_ranks - r_eps(theta)||^2, averaged over batch.

  Maximizing Spearman's rho is equivalent to minimizing the squared loss
  between ranks (paper §6.3); the soft rank makes it differentiable.
  Callers ranking the same scores more than once per step (e.g. an eps
  sweep, or ranking both directions) should build one
  ``SortContext(theta)`` and pass it here so every call shares a single
  argsort.
  """
  r = soft_rank(theta, regularization_strength, regularization, direction,
                plan=plan, sort_context=sort_context)
  per_example = 0.5 * jnp.sum((r - target_ranks) ** 2, axis=-1)
  return jnp.mean(per_example)


def spearman_correlation(pred_ranks: Array, target_ranks: Array) -> Array:
  """Hard Spearman's rho between two rank vectors (metric, last axis)."""
  def _center(x):
    return x - jnp.mean(x, axis=-1, keepdims=True)

  a, b = _center(pred_ranks), _center(target_ranks)
  num = jnp.sum(a * b, axis=-1)
  den = jnp.sqrt(jnp.sum(a * a, axis=-1) * jnp.sum(b * b, axis=-1))
  return num / jnp.maximum(den, 1e-12)


def hard_rank(theta: Array, direction: str = "ASCENDING") -> Array:
  """Integer ranks 1..n (ties broken by order), non-differentiable."""
  sgn = 1.0 if direction == "DESCENDING" else -1.0
  sigma = jnp.argsort(-sgn * jax.lax.stop_gradient(theta), axis=-1,
                      stable=True)
  n = theta.shape[-1]
  ranks = jnp.zeros_like(theta)
  vals = jnp.broadcast_to(
      jnp.arange(1, n + 1, dtype=theta.dtype), theta.shape)
  return jnp.put_along_axis(ranks, sigma, vals, axis=-1, inplace=False)


# ---------------------------------------------------------------------------
# Top-k classification (§6.1)
# ---------------------------------------------------------------------------


def soft_topk_loss(
    theta: Array,
    labels: Array,
    k: int = 1,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    squash: bool = True,
    plan=None,
) -> Array:
  """Loss encouraging the true label to appear in the soft top-k.

  Follows the paper's §6.1 recipe (after Cuturi et al. 2019): scores are
  squashed to [0,1] by a logistic map, soft-ranked (descending, rank 1 =
  best), and the loss penalizes the true label's soft rank exceeding k.
  """
  if squash:
    theta = jax.nn.sigmoid(theta)
  r = soft_rank(theta, regularization_strength, regularization,
                direction="DESCENDING", plan=plan)
  r_true = jnp.take_along_axis(r, labels[..., None], axis=-1)[..., 0]
  return jnp.mean(jax.nn.relu(r_true - k))


def topk_accuracy(theta: Array, labels: Array, k: int = 1) -> Array:
  top = jnp.argsort(-jax.lax.stop_gradient(theta), axis=-1)[..., :k]
  return jnp.mean(jnp.any(top == labels[..., None], axis=-1))


# ---------------------------------------------------------------------------
# Soft least trimmed squares (§6.4)
# ---------------------------------------------------------------------------


def soft_lts_loss(
    losses: Array,
    trim_count: int,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    plan=None,
    sort_context: SortContext | None = None,
) -> Array:
  """Mean of the soft-sorted losses with the largest `trim_count` dropped.

  (paper Eq. 10): losses are soft-sorted descending and entries k+1..n are
  averaged.  eps -> 0 recovers hard least trimmed squares; eps -> inf
  recovers plain least squares (interpolation validated in benchmarks).
  A ``SortContext(losses)`` built by the caller lets repeated trims of
  the same residuals (IRLS-style steps, trim-fraction sweeps) share one
  argsort.
  """
  n = losses.shape[-1]
  s = soft_sort(losses, regularization_strength, regularization,
                direction="DESCENDING", plan=plan,
                sort_context=sort_context)
  kept = s[..., trim_count:]
  return jnp.sum(kept, axis=-1) / (n - trim_count)


def soft_trimmed_token_loss(
    token_losses: Array,
    trim_fraction: float,
    regularization_strength: float = 1.0,
    regularization: str = "l2",
    plan=None,
) -> Array:
  """Soft-LTS applied to a flat vector of per-token LM losses.

  The framework-scale use of §6.4: at batch*seq ~ 1e6 tokens per step only
  an O(n log n) operator is viable -- this is precisely the paper's claim.
  """
  flat = token_losses.reshape(-1)
  k = int(round(trim_fraction * flat.shape[0]))
  if k == 0:
    return jnp.mean(flat)
  return jnp.mean(
      soft_lts_loss(flat, k, regularization_strength, regularization,
                    plan=plan))

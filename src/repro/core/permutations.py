"""Sorting helpers that are safe to differentiate in this environment.

This jax build carries an old-style ``GatherDimensionNumbers`` (no
``operand_batching_dims``) while ``_sort_jvp`` passes the new kwargs, so any
attempt to differentiate through ``lax.sort`` / ``jnp.sort`` / ``argsort``
raises.  The a.e.-correct gradient of sorting is "apply the (locally
constant) permutation to the cotangent", so we compute permutations under
``stop_gradient`` and apply them with plain gathers — mathematically
identical to sort's own JVP rule, and robust here.  (Documented in
DESIGN.md §10.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def argsort_descending(x: Array, axis: int = -1) -> Array:
  """Non-differentiable descending argsort (stable)."""
  return jnp.argsort(-lax.stop_gradient(x), axis=axis, stable=True)


def argsort_ascending(x: Array, axis: int = -1) -> Array:
  return jnp.argsort(lax.stop_gradient(x), axis=axis, stable=True)


def sort_descending(x: Array) -> tuple[Array, Array]:
  """Differentiable descending sort along the last axis.

  Returns (sorted values, permutation sigma) with gradient flowing through
  the gather (the exact a.e. Jacobian of sorting: the permutation matrix).
  """
  sigma = argsort_descending(x)
  return jnp.take_along_axis(x, sigma, axis=-1), sigma


def inverse_permutation(sigma: Array) -> Array:
  """sigma^{-1} along the last axis."""
  n = sigma.shape[-1]
  iota = jnp.broadcast_to(jnp.arange(n, dtype=sigma.dtype), sigma.shape)
  out = jnp.zeros_like(sigma)
  return jnp.put_along_axis(out, sigma, iota, axis=-1, inplace=False)


def apply_inverse_permutation(v: Array, sigma: Array) -> Array:
  """Compute v_{sigma^{-1}} (paper notation) differentiably.

  out[sigma_k] = v_k — a scatter whose transpose is the matching gather.
  """
  out = jnp.zeros_like(v)
  return jnp.put_along_axis(out, sigma, v, axis=-1, inplace=False)

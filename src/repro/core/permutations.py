"""Sorting helpers that are safe to differentiate in this environment.

This jax build carries an old-style ``GatherDimensionNumbers`` (no
``operand_batching_dims``) while ``_sort_jvp`` passes the new kwargs, so any
attempt to differentiate through ``lax.sort`` / ``jnp.sort`` / ``argsort``
raises.  The a.e.-correct gradient of sorting is "apply the (locally
constant) permutation to the cotangent", so we compute permutations under
``stop_gradient`` and apply them with plain gathers — mathematically
identical to sort's own JVP rule, and robust here.  (Documented in
DESIGN.md §10.)

Fast path
---------
XLA:CPU (and GPU) have a radix-style fast path for *single-operand integer*
sorts, while any variadic/comparator sort (``argsort``, value+index pair
sorts, float sorts) falls back to a ~4-6x slower comparison sort.
``argsort_descending_fast`` exploits this: f32 keys are bitcast to u32,
mapped through the order-preserving total order on float bits, packed with
the position index into one u64 word (``bitcast_convert_type`` of a
trailing ``(..., 2)`` u32 axis — no 64-bit constants, so it lowers cleanly
whatever the x64 mode), and sorted as a single integer key.  The low word
of the result is a stable argsort permutation and the high word unpacks
*bit-exactly* to the sorted values.  ``invert_permutation_fast`` applies
the same trick to invert a permutation without a scatter.  The only
divergence from ``jnp.argsort`` semantics: ``-0.0`` and ``+0.0`` are
ordered by their (distinct) bit patterns rather than treated as equal keys
— numerically irrelevant downstream, where equal values merge into one
isotonic block anyway.

Staging caveat: the packed fast path must NOT be traced inside a
``jax.custom_vjp`` body.  Lowering a custom_vjp sub-jaxpr with global x64
off re-canonicalizes the size-changing u32 -> u64 bitcast into a
shape-preserving u32 no-op, which splits the packed sort into independent
word sorts (the permutation payload silently becomes identity).  Callers
that wrap a pipeline in custom_vjp (the fused projection) compute these
sorts in the surrounding trace context and pass the permutations in as
residual arguments instead.

All permutations produced by this module are int32 end-to-end (an n that
overflows int32 would OOM long before the index dtype matters).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_INT = jnp.int32
_SIGN_BIT = 0x80000000
# u32 inverse packing needs sigma * n + iota < 2**32.
_U32_INVERT_MAX_N = 65535


def argsort_descending(x: Array, axis: int = -1) -> Array:
  """Non-differentiable descending argsort (stable, int32)."""
  return jnp.argsort(-lax.stop_gradient(x), axis=axis,
                     stable=True).astype(_INT)


def argsort_ascending(x: Array, axis: int = -1) -> Array:
  return jnp.argsort(lax.stop_gradient(x), axis=axis,
                     stable=True).astype(_INT)


def sort_descending(x: Array) -> tuple[Array, Array]:
  """Differentiable descending sort along the last axis.

  Returns (sorted values, permutation sigma) with gradient flowing through
  the gather (the exact a.e. Jacobian of sorting: the permutation matrix).
  """
  sigma = argsort_descending(x)
  return jnp.take_along_axis(x, sigma, axis=-1), sigma


def inverse_permutation(sigma: Array) -> Array:
  """sigma^{-1} along the last axis (int32)."""
  sigma = sigma.astype(_INT)
  n = sigma.shape[-1]
  iota = jnp.broadcast_to(jnp.arange(n, dtype=_INT), sigma.shape)
  out = jnp.zeros_like(sigma)
  return jnp.put_along_axis(out, sigma, iota, axis=-1, inplace=False)


def apply_inverse_permutation(v: Array, sigma: Array) -> Array:
  """Compute v_{sigma^{-1}} (paper notation) differentiably.

  out[sigma_k] = v_k — a scatter whose transpose is the matching gather.
  """
  out = jnp.zeros_like(v)
  return jnp.put_along_axis(out, sigma, v, axis=-1, inplace=False)


# ---------------------------------------------------------------------------
# Packed single-key sorts (the integer-sort fast path).
# ---------------------------------------------------------------------------


def _packed_sort_u64(hi: Array, lo: Array) -> tuple[Array, Array]:
  """Ascending sort of the u64 keys (hi << 32) | lo; returns (hi, lo) sorted.

  Packing is a size-changing ``bitcast_convert_type`` of a trailing
  ``(..., 2)`` u32 axis (little-endian: element 0 is the low word), which
  avoids 64-bit *constants* entirely: jaxpr constants are re-canonicalized
  to 32 bits at lowering time when global x64 is off, so a
  ``jnp.uint64(32)`` shift amount would miscompile even inside an
  ``enable_x64`` trace scope.
  """
  with jax.experimental.enable_x64(True):
    packed = lax.bitcast_convert_type(jnp.stack([lo, hi], axis=-1),
                                      jnp.uint64)
    skeys = lax.sort(packed, dimension=-1, is_stable=False)
    unpacked = lax.bitcast_convert_type(skeys, jnp.uint32)
  return unpacked[..., 1], unpacked[..., 0]


def _f32_total_order_keys(x: Array, descending: bool) -> Array:
  """u32 keys whose unsigned order is the total order on f32 bit patterns."""
  b = lax.bitcast_convert_type(x, jnp.uint32)
  sign = jnp.uint32(_SIGN_BIT)
  asc = jnp.where((b & sign) != 0, ~b, b | sign)
  return ~asc if descending else asc


def _keys_to_f32(keys: Array, descending: bool) -> Array:
  """Invert ``_f32_total_order_keys`` — bit-exact value recovery."""
  sign = jnp.uint32(_SIGN_BIT)
  asc = ~keys if descending else keys
  b = jnp.where((asc & sign) != 0, asc & ~sign, ~asc)
  return lax.bitcast_convert_type(b, jnp.float32)


def _fast_sort_ok(x: Array) -> bool:
  """Packed u64 path: f32 keys only, and not on TPU (no 64-bit integers)."""
  return (x.dtype == jnp.float32 and x.ndim >= 1
          and jax.default_backend() != "tpu")


def argsort_descending_fast(x: Array) -> tuple[Array, Array]:
  """(sorted values, sigma int32) descending along the last axis.

  Single u64 integer sort on f32/CPU/GPU (~4x faster than the comparator
  argsort at n=1024); falls back to ``sort_descending`` semantics (under
  ``stop_gradient``) for other dtypes/platforms.  Non-differentiable: both
  outputs are detached — callers on the fused projection path own their
  gradients.
  """
  x = lax.stop_gradient(x)
  if not _fast_sort_ok(x):
    sigma = argsort_descending(x)
    return jnp.take_along_axis(x, sigma, axis=-1), sigma
  n = x.shape[-1]
  keys = _f32_total_order_keys(x, descending=True)
  iota = jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32), x.shape)
  skeys, sigma = _packed_sort_u64(keys, iota)
  return _keys_to_f32(skeys, descending=True), sigma.astype(_INT)


def invert_permutation_fast(sigma: Array) -> Array:
  """sigma^{-1} (int32) without a scatter: one packed integer sort.

  For n <= 65535 the (position-in-sorted-order, original-index) pair packs
  into a single u32 key (``sigma * n + iota``); larger n (or TPU, which
  has no u64) uses the u64 pack / an explicit scatter respectively.
  """
  n = sigma.shape[-1]
  if jax.default_backend() == "tpu" and n > _U32_INVERT_MAX_N:
    return inverse_permutation(sigma)
  iota = jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32), sigma.shape)
  sig_u = sigma.astype(jnp.uint32)
  if n <= _U32_INVERT_MAX_N:
    packed = sig_u * jnp.uint32(n) + iota
    inv = lax.sort(packed, dimension=-1, is_stable=False) % jnp.uint32(n)
  else:
    _, inv = _packed_sort_u64(sig_u, iota)
  return inv.astype(_INT)


# ---------------------------------------------------------------------------
# Sort reuse across operators.
# ---------------------------------------------------------------------------


class SortContext:
  """Caches the argsort of one tensor so several operators share one sort.

  Build it once on the raw values and pass it to every soft operator that
  sees the *same* tensor (``soft_rank`` twice in a Spearman loss, the
  ``soft_sort``/``soft_quantile`` pair, an eps sweep over identical
  scores): each direction's (sorted values, sigma, sigma^{-1}) triple is
  computed on first use and served from cache afterwards, recorded as
  ``sort_reuse_hit`` in ``repro.obs.metrics``.

  Trace-time caveat: the cache holds *traced* arrays, so a context is only
  valid within the jit trace (or eager region) whose ``values`` it was
  built from — build it inside the jitted function, next to the operator
  calls that share it.
  """

  def __init__(self, values: Array):
    self.values = jnp.asarray(values)
    self._cache: dict[bool, tuple[Array, Array, Array]] = {}

  def _get(self, descending: bool) -> tuple[Array, Array, Array]:
    hit = descending in self._cache
    if not hit:
      x = self.values if descending else -self.values
      s, sigma = argsort_descending_fast(x)
      self._cache[descending] = (s if descending else -s, sigma,
                                 invert_permutation_fast(sigma))
    from repro.obs import metrics as _metrics  # lazy: keep import light
    _metrics.counter_inc("sort_reuse_hit" if hit else "sort_reuse_miss",
                         source="sort_context")
    return self._cache[descending]

  def descending(self) -> tuple[Array, Array, Array]:
    """(values sorted descending, sigma, sigma^{-1}), all detached."""
    return self._get(True)

  def ascending(self) -> tuple[Array, Array, Array]:
    """(values sorted ascending, sigma, sigma^{-1}), all detached."""
    return self._get(False)

"""The paper's comparison baselines, implemented faithfully in JAX.

- OT / Sinkhorn soft sort & rank (Cuturi et al., 2019): O(T m n) time,
  O(n^2) memory for m = n; differentiation unrolls Sinkhorn iterates.
- All-pairs soft rank (Qin et al., 2010): O(n^2) sigmoid comparisons.

Used by ``benchmarks/bench_runtime.py`` to reproduce Figure 4 (right) and by
accuracy benchmarks as drop-in alternatives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def allpairs_rank(theta: Array, temperature: float = 1.0) -> Array:
  """r_i = 1 + sum_j sigmoid((theta_j - theta_i)/tau); descending ranks."""
  diff = theta[..., None, :] - theta[..., :, None]  # [.., i, j] = th_j - th_i
  pair = jax.nn.sigmoid(diff / temperature)
  n = theta.shape[-1]
  eye = jnp.eye(n, dtype=theta.dtype)
  pair = pair * (1.0 - eye)
  return 1.0 + jnp.sum(pair, axis=-1)


def _sinkhorn(log_k: Array, num_iters: int) -> Array:
  """Log-domain Sinkhorn onto uniform marginals; returns log coupling."""
  n, m = log_k.shape[-2], log_k.shape[-1]
  log_a = -jnp.log(n) * jnp.ones(log_k.shape[:-1])
  log_b = -jnp.log(m) * jnp.ones(log_k.shape[:-2] + (m,))

  def body(carry, _):
    f, g = carry
    f = log_a - jax.scipy.special.logsumexp(log_k + g[..., None, :], axis=-1)
    g = log_b - jax.scipy.special.logsumexp(log_k + f[..., None], axis=-2)
    return (f, g), None

  f0 = jnp.zeros(log_k.shape[:-1])
  g0 = jnp.zeros(log_k.shape[:-2] + (m,))
  (f, g), _ = lax.scan(body, (f0, g0), None, length=num_iters)
  return log_k + f[..., None] + g[..., None, :]


def ot_rank_and_sort(
    theta: Array,
    epsilon: float = 1e-2,
    num_iters: int = 100,
) -> tuple[Array, Array]:
  """OT soft rank & sort of Cuturi et al. (m = n, squared cost).

  Returns (soft_ranks, soft_sorted) with descending-rank convention
  (rank 1 = largest), matching ``repro.core.operators``.
  """
  n = theta.shape[-1]
  rho = jnp.arange(n, 0, -1, dtype=theta.dtype)
  # Squash as in the reference implementation to keep the cost well-scaled.
  t = jax.nn.sigmoid(theta)
  r = jax.nn.sigmoid(rho / n)
  cost = 0.5 * (-t[..., :, None] + r[None, :]) ** 2  # D(-theta, rho)
  log_p = _sinkhorn(-cost / epsilon, num_iters)
  p = jnp.exp(log_p)  # ~doubly stochastic / n
  # Position j holds sorted-descending slot j, i.e. rank j+1.
  ranks_by_pos = jnp.arange(1, n + 1, dtype=theta.dtype)
  soft_ranks = n * jnp.einsum("...ij,j->...i", p, ranks_by_pos)
  soft_sorted = n * jnp.einsum("...ij,...i->...j", p, theta)
  return soft_ranks, soft_sorted


def ot_rank(theta: Array, epsilon: float = 1e-2, num_iters: int = 100):
  return ot_rank_and_sort(theta, epsilon, num_iters)[0]


def ot_sort(theta: Array, epsilon: float = 1e-2, num_iters: int = 100):
  return ot_rank_and_sort(theta, epsilon, num_iters)[1]

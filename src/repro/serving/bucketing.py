"""Shape-bucket policy: which padded width serves a request of size n.

Buckets trade compile count against padding waste: every distinct
(rows, n) pair is its own XLA program, so the engine quantizes request
sizes onto a small ladder (default: powers of two) and batch sizes onto
a pow2 row ladder up to ``max_batch``.

``BucketPolicy.from_plan`` additionally splices the active
:class:`repro.plan.ExecutionPlan`'s shape breakpoints into the ladder,
so no bucket straddles a backend cutoff — a request that the plan would
route to the small-n backend is never padded past the cutoff into the
large-n backend's regime.
"""

from __future__ import annotations

import dataclasses

from repro import plan as plan_mod


def _pow2_ladder(lo: int, hi: int) -> tuple[int, ...]:
  if lo < 1 or hi < lo:
    raise ValueError(f"invalid ladder bounds [{lo}, {hi}]")
  sizes = []
  b = 1
  while b < lo:
    b *= 2
  while b < hi:
    sizes.append(b)
    b *= 2
  sizes.append(hi)
  return tuple(sizes)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
  """Sorted ladders of padded problem sizes and batch-row sizes."""

  sizes: tuple[int, ...]
  row_sizes: tuple[int, ...]

  def __post_init__(self):
    for name, ladder in (("sizes", self.sizes), ("row_sizes", self.row_sizes)):
      if not ladder or list(ladder) != sorted(set(ladder)):
        raise ValueError(f"{name} must be a non-empty sorted unique ladder, "
                         f"got {ladder!r}")
      if ladder[0] < 1:
        raise ValueError(f"{name} entries must be >= 1, got {ladder!r}")

  @classmethod
  def pow2(cls, min_n: int = 64, max_n: int = 4096,
           max_batch: int = 64) -> "BucketPolicy":
    """Power-of-two ladder: min_n, 2*min_n, ..., max_n; rows 1..max_batch."""
    return cls(sizes=_pow2_ladder(min_n, max_n),
               row_sizes=_pow2_ladder(1, max_batch))

  @classmethod
  def from_plan(cls, plan=None, *, min_n: int = 64, max_n: int = 4096,
                max_batch: int = 64) -> "BucketPolicy":
    """pow2 ladder refined with the plan chain's n-breakpoints.

    ``plan=None`` uses whatever plan currently governs dispatch (active >
    packaged default > builtin), mirroring the resolution chain.
    """
    base = set(_pow2_ladder(min_n, max_n))
    for edge in plan_mod.shape_breakpoints(plan):
      if min_n <= edge <= max_n:
        base.add(edge)
    sizes = tuple(sorted(base))
    return cls(sizes=sizes, row_sizes=_pow2_ladder(1, max_batch))

  @property
  def max_n(self) -> int:
    return self.sizes[-1]

  @property
  def max_rows(self) -> int:
    return self.row_sizes[-1]

  def bucket_for(self, n: int) -> int:
    """Smallest bucket >= n; raises for n out of the serviceable range."""
    if n < 1:
      raise ValueError(f"request size must be >= 1, got {n}")
    for b in self.sizes:
      if n <= b:
        return b
    raise ValueError(
        f"request size n={n} exceeds the largest bucket {self.sizes[-1]}")

  def rows_for(self, m: int) -> int:
    """Smallest row bucket >= m (m is clamped to max_rows by callers)."""
    if m < 1:
      raise ValueError(f"row count must be >= 1, got {m}")
    for b in self.row_sizes:
      if m <= b:
        return b
    raise ValueError(
        f"row count {m} exceeds the largest row bucket {self.row_sizes[-1]}")

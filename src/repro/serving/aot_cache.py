"""Bounded LRU of ahead-of-time compiled executables.

Per-request ``jax.jit`` dispatch pays a Python-side cache probe plus —
on any novel shape — trace and compile time *on the request path*.  The
engine instead compiles each ``(op-variant, rows, bucket_n, dtype)``
cell once, ahead of time, via ``jax.jit(fn).lower(*specs).compile()``,
and calls the resulting executable directly.

Counters (``repro.obs.metrics``):

* ``aot_cache_hit`` — executable already resident;
* ``aot_cache_miss`` — compiled lazily on the request path (a warmup
  gap: the smoke gate requires this to be 0 after plan-derived warmup);
* ``aot_cache_warm`` — compiled by explicit warmup (not a miss);
* ``aot_cache_evict`` — LRU eviction under the capacity bound.
"""

from __future__ import annotations

import collections
import threading
from typing import Callable, Hashable

from repro.obs import metrics


class AOTExecutableCache:
  """LRU mapping hashable keys -> compiled executables (thread-safe)."""

  def __init__(self, capacity: int = 128):
    if capacity < 1:
      raise ValueError(f"capacity must be >= 1, got {capacity}")
    self.capacity = capacity
    self._entries: "collections.OrderedDict[Hashable, object]" = (
        collections.OrderedDict())
    self._lock = threading.Lock()

  def __len__(self) -> int:
    return len(self._entries)

  def __contains__(self, key: Hashable) -> bool:
    return key in self._entries

  def keys(self):
    return list(self._entries)

  def get(self, key: Hashable, builder: Callable[[], object]) -> object:
    """The executable for ``key``, compiling via ``builder()`` on miss."""
    with self._lock:
      exe = self._entries.get(key)
      if exe is not None:
        self._entries.move_to_end(key)
        metrics.counter_inc("aot_cache_hit")
        return exe
    # Compile outside the lock (compilation can take seconds); a racing
    # duplicate compile is wasteful but correct — last insert wins.
    metrics.counter_inc("aot_cache_miss")
    exe = builder()
    self._insert(key, exe)
    return exe

  def warm(self, key: Hashable, builder: Callable[[], object]) -> bool:
    """Populate ``key`` ahead of traffic; True if a compile happened.

    Warmup compiles count as ``aot_cache_warm``, not misses — so a
    nonzero ``aot_cache_miss`` after warmup always means the request
    stream hit a bucket warmup did not enumerate.
    """
    with self._lock:
      if key in self._entries:
        self._entries.move_to_end(key)
        return False
    metrics.counter_inc("aot_cache_warm")
    exe = builder()
    self._insert(key, exe)
    return True

  def _insert(self, key: Hashable, exe: object) -> None:
    with self._lock:
      self._entries[key] = exe
      self._entries.move_to_end(key)
      while len(self._entries) > self.capacity:
        self._entries.popitem(last=False)
        metrics.counter_inc("aot_cache_evict")

  def clear(self) -> None:
    with self._lock:
      self._entries.clear()

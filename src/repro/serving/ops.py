"""Padded batched op family with *exact* (bitwise) padding semantics.

The batcher pads every request up to its shape bucket.  Naive padding
(zeros, repeats of the last element) changes the isotonic problem: pads
can pool with real entries and perturb every output lane.  This module
constructs pads so that, per row with true length ``n`` inside a bucket
of width ``N``:

1. **Pads sort strictly below every real entry** — so after the
   descending sort the real entries occupy positions ``0..n-1`` in the
   same order as the unpadded call, and all prefix arithmetic (the lax
   sequential PAV, the pow2-aligned d&c merge tree of the ``scan``
   backend, and the index-0-aligned ``associative_scan`` of ``minimax``)
   is performed on bitwise-identical operands.
2. **No isotonic block ever pools across the real/pad boundary** — the
   first pad sits below the smallest achievable real block value by a
   margin ``M(N) = 132 + 2*log(N+1)``, and successive pads keep
   descending by at least that margin, so PAV never merges across the
   boundary and minimax's crossing intervals always lose the inner max.
3. **KL stays bitwise too** — the 132 in the margin exceeds the float32
   ``exp`` underflow threshold (~104), so every log-sum-exp that crosses
   into the pad region adds ``exp(pad - acc) == 0.0`` *exactly* and
   ``logaddexp`` returns the real-prefix accumulator bit-for-bit.

The result: ``padded_op(values_padded)[..., :n]`` is bitwise equal to
the unpadded operator per backend for soft_sort / soft_rank / soft_topk
/ projection (property-tested in tests/test_padding_invariance.py).
Scalar losses (Spearman, LTS) are masked reductions over those exact
vectors; their reduce tree differs between ``n`` and ``N`` so they are
allclose, not bitwise.

Every op takes the uniform traced signature

    fn(values (B, N) f32, true_n (B,) i32, eps (B,) f32, *extras)

with per-request parameters (``eps``, ``k``, ``trim``) as *traced*
per-row arrays — so one compiled executable serves any mix of request
parameters and the AOT cache key stays ``(op, variant, rows, bucket)``.
Static variant choices (regularization, direction) are baked into
module-level ``functools.partial`` objects, giving each variant a
process-stable callable identity (jit trace caches and
``dispatch.stable_entry`` rely on this).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.losses import soft_spearman_loss  # noqa: F401  (doc x-ref)
from repro.core.projection import projection_permutahedron

Array = jax.Array

#: Extra argument kinds: a scalar per row, or a full (B, N) row vector.
EXTRA_SCALAR = "scalar_per_row"
EXTRA_VECTOR = "row_vector"


def margin(bucket_n: int) -> float:
  """Separation margin between consecutive pad entries.

  128 clears the float32 ``exp`` underflow threshold (exp(x) == 0.0 for
  x < -103.98) with slack; ``2*log(N+1)`` absorbs log-sum-exp
  accumulation over up to N terms on both sides of a KL block value;
  +4 is headroom for the last-ulp of masked min/max reductions.
  """
  return 128.0 + 2.0 * math.log(bucket_n + 1.0) + 4.0


def _row_geometry(values: Array, true_n: Array):
  """(idx, mask, tail_k) for a (B, N) batch.

  ``mask`` is True on real lanes; ``tail_k`` counts pad positions
  1, 2, ... within the pad region (arbitrary <= 0 on real lanes).
  """
  n_bucket = values.shape[-1]
  idx = jnp.arange(n_bucket, dtype=jnp.int32)[None, :]
  nn = true_n[:, None]
  mask = idx < nn
  tail_k = (idx - nn + 1).astype(values.dtype)
  return idx, nn, mask, tail_k


def _masked_min(x: Array, mask: Array) -> Array:
  return jnp.min(jnp.where(mask, x, jnp.inf), axis=-1, keepdims=True)


def _masked_max(x: Array, mask: Array) -> Array:
  return jnp.max(jnp.where(mask, x, -jnp.inf), axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# The padded operators.
#
# Shared shape of the argument: real prefix reproduces the unpadded
# operator's (z, w) bit-for-bit; the pad tail extends z strictly
# descending with per-step drop D >= margin(N) (plus the weight range
# where the weights vary), and extends w weakly descending strictly
# below (or equal-at-the-bottom to) the real weights.
# ---------------------------------------------------------------------------


def _padded_soft_sort(values: Array, true_n: Array, eps: Array, *,
                      regularization: str, direction: str,
                      impl=None, plan=None) -> Array:
  """Bucket-padded soft_sort; out[:, :n] bitwise == unpadded soft_sort."""
  descending = direction == "DESCENDING"
  vv = values if descending else -values
  idx, nn, mask, tail_k = _row_geometry(values, true_n)
  e = eps[:, None]
  # Real prefix: z = rho_n / eps exactly ((n - idx) is integer-exact in
  # f32).  Pads keep descending by 1/eps + D per step.
  z_ladder = (nn - idx).astype(values.dtype) / e
  mn_v = _masked_min(vv, mask)
  d_step = (_masked_max(vv, mask) - mn_v) + margin(values.shape[-1])
  z = jnp.where(mask, z_ladder, z_ladder - tail_k * d_step)
  w = jnp.where(mask, vv, mn_v - 1.0)
  out = projection_permutahedron(
      z, w, regularization, impl, plan=plan, z_is_sorted=True)
  out = out if descending else -out
  return jnp.where(mask, out, 0.0)


def _padded_soft_rank(values: Array, true_n: Array, eps: Array, *,
                      regularization: str, direction: str,
                      impl=None, plan=None) -> Array:
  """Bucket-padded soft_rank; out[:, :n] bitwise == unpadded soft_rank."""
  descending = direction == "DESCENDING"
  idx, nn, mask, tail_k = _row_geometry(values, true_n)
  e = eps[:, None]
  z_real = (-values if descending else values) / e
  # Whole-row weight ladder (n, n-1, ..., 1, 0, -1, ...): the real
  # prefix is exactly rho_n and the tail keeps strictly descending, so
  # w_is_sorted holds for the full bucket row.
  w = (nn - idx).astype(values.dtype)
  mn_z = _masked_min(z_real, mask)
  d_step = values.shape[-1] + margin(values.shape[-1])
  z = jnp.where(mask, z_real, mn_z - tail_k * d_step)
  out = projection_permutahedron(
      z, w, regularization, impl, plan=plan, w_is_sorted=True)
  return jnp.where(mask, out, 0.0)


def _padded_soft_topk(values: Array, true_n: Array, eps: Array, k: Array, *,
                      regularization: str, impl=None, plan=None) -> Array:
  """Bucket-padded soft_topk_mask with per-row traced k."""
  idx, nn, mask, tail_k = _row_geometry(values, true_n)
  e = eps[:, None]
  z_real = values / e
  # k ones then zeros — pads fall in the zero region, so the whole-row
  # indicator is the real weight vector extended by (exact) zeros.
  w = (idx < k[:, None]).astype(values.dtype)
  mn_z = _masked_min(z_real, mask)
  z = jnp.where(mask, z_real, mn_z - tail_k * margin(values.shape[-1]))
  out = projection_permutahedron(
      z, w, regularization, impl, plan=plan, w_is_sorted=True)
  return jnp.where(mask, out, 0.0)


def _padded_projection(values: Array, true_n: Array, eps: Array, w: Array, *,
                       regularization: str, impl=None, plan=None) -> Array:
  """Bucket-padded generic P_Psi(z, w); ``values`` is z, ``eps`` unused
  (kept for the uniform serving signature)."""
  del eps
  idx, nn, mask, tail_k = _row_geometry(values, true_n)
  mn_z = _masked_min(values, mask)
  mn_w = _masked_min(w, mask)
  d_step = (_masked_max(w, mask) - mn_w) + margin(values.shape[-1])
  z_pad = jnp.where(mask, values, mn_z - tail_k * d_step)
  w_pad = jnp.where(mask, w, mn_w - 1.0)
  out = projection_permutahedron(z_pad, w_pad, regularization, impl, plan=plan)
  return jnp.where(mask, out, 0.0)


def _padded_spearman(values: Array, true_n: Array, eps: Array,
                     target: Array, *, regularization: str, direction: str,
                     impl=None, plan=None) -> Array:
  """Per-row soft Spearman loss over bucket-padded rows.

  Masked reduction over the exact padded soft_rank — allclose to the
  unpadded loss (the sum's reduce tree differs between n and N).
  """
  ranks = _padded_soft_rank(values, true_n, eps,
                            regularization=regularization,
                            direction=direction, impl=impl, plan=plan)
  _, _, mask, _ = _row_geometry(values, true_n)
  sq = jnp.where(mask, (ranks - target) ** 2, 0.0)
  return 0.5 * jnp.sum(sq, axis=-1)


def _padded_lts(values: Array, true_n: Array, eps: Array, trim: Array, *,
                regularization: str, impl=None, plan=None) -> Array:
  """Per-row soft least-trimmed-squares loss over bucket-padded rows."""
  s = _padded_soft_sort(values, true_n, eps, regularization=regularization,
                        direction="DESCENDING", impl=impl, plan=plan)
  idx, nn, mask, _ = _row_geometry(values, true_n)
  kept = mask & (idx >= trim[:, None])
  total = jnp.sum(jnp.where(kept, s, 0.0), axis=-1)
  denom = (true_n - trim).astype(values.dtype)
  return total / denom


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpSpec:
  """One servable (op, regularization, direction) variant.

  ``fn`` has the uniform traced signature
  ``fn(values, true_n, eps, *extras, impl=..., plan=...)`` and is a
  module-level ``functools.partial`` (stable identity per process).
  """

  op: str
  regularization: str
  direction: str                       # "" when the op has no direction
  extras: tuple[tuple[str, str, str], ...]  # (name, dtype, kind)
  output: str                          # "vector" | "scalar"
  exact: bool                          # bitwise padding contract holds
  fn: Callable

  @property
  def key(self) -> str:
    parts = [self.op, self.regularization]
    if self.direction:
      parts.append("desc" if self.direction == "DESCENDING" else "asc")
    return "/".join(parts)


def _specs() -> dict[str, OpSpec]:
  out: dict[str, OpSpec] = {}

  def add(spec: OpSpec):
    out[spec.key] = spec

  for reg in ("l2", "kl"):
    for direction in ("DESCENDING", "ASCENDING"):
      add(OpSpec("soft_sort", reg, direction, (), "vector", True,
                 functools.partial(_padded_soft_sort, regularization=reg,
                                   direction=direction)))
      add(OpSpec("soft_rank", reg, direction, (), "vector", True,
                 functools.partial(_padded_soft_rank, regularization=reg,
                                   direction=direction)))
      add(OpSpec("spearman", reg, direction,
                 (("target", "float32", EXTRA_VECTOR),), "scalar", False,
                 functools.partial(_padded_spearman, regularization=reg,
                                   direction=direction)))
    add(OpSpec("soft_topk", reg, "",
               (("k", "int32", EXTRA_SCALAR),), "vector", True,
               functools.partial(_padded_soft_topk, regularization=reg)))
    add(OpSpec("projection", reg, "",
               (("w", "float32", EXTRA_VECTOR),), "vector", True,
               functools.partial(_padded_projection, regularization=reg)))
    add(OpSpec("lts", reg, "",
               (("trim", "int32", EXTRA_SCALAR),), "scalar", False,
               functools.partial(_padded_lts, regularization=reg)))
  return out


#: key ("soft_sort/l2/desc", "lts/kl", ...) -> OpSpec
SERVING_OPS: dict[str, OpSpec] = _specs()


def padded_op(key: str) -> OpSpec:
  """Look up an OpSpec by its key, with a helpful error."""
  try:
    return SERVING_OPS[key]
  except KeyError:
    raise KeyError(
        f"unknown serving op {key!r}; expected one of "
        f"{sorted(SERVING_OPS)}") from None


@functools.lru_cache(maxsize=None)
def bound_op(key: str, impl: str | None = None, plan=None) -> Callable:
  """``spec.fn`` with backend/plan pinned, with stable identity.

  Same (key, impl, plan) -> same callable object, so ``jax.jit`` trace
  caches and the serving AOT cache see one function per configuration
  (``ExecutionPlan`` is hashable by design).  The companion for raw
  dispatch entries is ``repro.kernels.dispatch.stable_entry``.
  """
  spec = padded_op(key)
  return functools.partial(spec.fn, impl=impl, plan=plan)

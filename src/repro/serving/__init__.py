"""`repro.serving` — shape-bucketed dynamic batching for the soft-op family.

The paper's operators are fast enough (O(n log n), exact) to sit on a
request hot path — but only if the serving layer feeds the batched
kernels properly.  This package turns a stream of heterogeneous
single requests (arbitrary ``n``, per-request ``eps``/direction/params)
into saturated batched kernel launches:

* :mod:`repro.serving.bucketing` — shape-bucket policy (pow2 ladder,
  optionally refined with the active :class:`repro.plan.ExecutionPlan`'s
  rule breakpoints so no bucket straddles a backend cutoff);
* :mod:`repro.serving.ops` — the padded batched op family.  Requests are
  padded *exactly*: every pad element sorts strictly below the real
  entries and is separated by enough margin that no isotonic block ever
  pools across the real/pad boundary, so the sliced-back result is
  bitwise identical to the unpadded call, per backend (the contract the
  batcher relies on; property-tested in tests/test_padding_invariance.py);
* :mod:`repro.serving.aot_cache` — bounded LRU of ahead-of-time compiled
  executables (``jax.jit(...).lower(...).compile()``), keyed by
  ``(op, regularization, direction, rows, bucket_n)`` and warmable at
  startup so the first real request never pays compilation;
* :mod:`repro.serving.admission` — bounded admission queue with typed
  load-shedding (reject-on-full, expire-in-queue) — never exceptions;
* :mod:`repro.serving.engine` — the micro-batching engine tying it all
  together under a configurable max-wait / max-batch policy, with full
  ``repro.obs`` integration (``serving_admit`` / ``serving_shed`` /
  ``aot_cache_{hit,miss,evict}`` counters; queue-depth, batch-occupancy,
  padding-waste and latency histograms).

See docs/SERVING.md for architecture, bucketing/deadline semantics, the
warmup workflow and the counter reference.
"""

from repro.serving.admission import (
    AdmissionQueue,
    Request,
    ServeResult,
    STATUS_ERROR,
    STATUS_OK,
    STATUS_SHED_DEADLINE,
    STATUS_SHED_QUEUE_FULL,
)
from repro.serving.aot_cache import AOTExecutableCache
from repro.serving.bucketing import BucketPolicy
from repro.serving.engine import (
    EngineConfig,
    ServingEngine,
    synthetic_stream,
)
from repro.serving.ops import SERVING_OPS, padded_op

__all__ = [
    "AOTExecutableCache",
    "AdmissionQueue",
    "BucketPolicy",
    "EngineConfig",
    "Request",
    "ServeResult",
    "ServingEngine",
    "SERVING_OPS",
    "STATUS_ERROR",
    "STATUS_OK",
    "STATUS_SHED_DEADLINE",
    "STATUS_SHED_QUEUE_FULL",
    "padded_op",
    "synthetic_stream",
]

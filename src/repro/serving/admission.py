"""Admission control: typed requests/results and the bounded queue.

Load-shedding is part of the result type, never an exception: a request
that cannot be served returns a :class:`ServeResult` whose ``status``
says why (``shed_queue_full`` at admission when the bounded queue is
full; ``shed_deadline`` when its deadline expires while queued).  The
engine's counters mirror the statuses (``serving_admit``,
``serving_shed{reason=...}``).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any

import numpy as np

STATUS_OK = "ok"
STATUS_SHED_QUEUE_FULL = "shed_queue_full"
STATUS_SHED_DEADLINE = "shed_deadline"
STATUS_ERROR = "error"

_ids = itertools.count()


@dataclasses.dataclass
class Request:
  """One serving request: a single row of arbitrary length ``n``.

  ``op`` is an :data:`repro.serving.ops.SERVING_OPS` key (e.g.
  ``"soft_rank/l2/desc"``); ``extras`` carries the op's per-request
  parameters (``k``, ``trim`` scalars; ``target``, ``w`` length-n
  vectors).  ``deadline_ms`` is a relative budget from submission;
  the engine stamps the absolute expiry on admission.
  """

  op: str
  values: np.ndarray
  eps: float = 1.0
  extras: dict[str, Any] = dataclasses.field(default_factory=dict)
  deadline_ms: float | None = None

  # Engine-stamped state.
  request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
  bucket_n: int = 0
  submitted_at: float = 0.0
  deadline_at: float | None = None
  _done: threading.Event = dataclasses.field(
      default_factory=threading.Event, repr=False, compare=False)
  _result: "ServeResult | None" = dataclasses.field(
      default=None, repr=False, compare=False)

  @property
  def n(self) -> int:
    return int(np.asarray(self.values).shape[-1])

  @property
  def group(self) -> tuple[str, int]:
    """Micro-batching key: requests batch together per (op, bucket)."""
    return (self.op, self.bucket_n)

  def finish(self, result: "ServeResult") -> None:
    self._result = result
    self._done.set()

  def result(self, timeout: float | None = None) -> "ServeResult":
    """Block until served/shed; raises TimeoutError if not done in time."""
    if not self._done.wait(timeout):
      raise TimeoutError(f"request {self.request_id} not finished "
                         f"within {timeout}s")
    assert self._result is not None
    return self._result

  def done(self) -> bool:
    return self._done.is_set()


@dataclasses.dataclass
class ServeResult:
  """Typed outcome of one request (statuses: ``ok``, ``shed_queue_full``,
  ``shed_deadline``, ``error`` — shedding is data, not an exception)."""

  status: str
  request_id: int
  op: str
  n: int
  value: Any = None          # (n,) array for vector ops, scalar for losses
  latency_us: float | None = None
  bucket_n: int | None = None
  rows: int | None = None    # batch rows of the executable that served it
  detail: str = ""

  @property
  def ok(self) -> bool:
    return self.status == STATUS_OK


class AdmissionQueue:
  """Bounded FIFO with group-aware draining and deadline expiry.

  Thread-safe; all methods take the internal lock.  ``clock`` is
  injectable (tests pin it) and defaults to ``time.monotonic``.
  """

  def __init__(self, capacity: int, clock=time.monotonic):
    if capacity < 1:
      raise ValueError(f"queue capacity must be >= 1, got {capacity}")
    self.capacity = capacity
    self.clock = clock
    self._items: list[Request] = []
    self._lock = threading.Lock()

  def __len__(self) -> int:
    return len(self._items)

  def try_push(self, req: Request) -> bool:
    """Admit ``req``; False (reject-on-full) when at capacity."""
    with self._lock:
      if len(self._items) >= self.capacity:
        return False
      self._items.append(req)
      return True

  def expire(self, now: float | None = None) -> list[Request]:
    """Remove and return every queued request whose deadline has passed."""
    now = self.clock() if now is None else now
    with self._lock:
      expired = [r for r in self._items
                 if r.deadline_at is not None and now > r.deadline_at]
      if expired:
        dead = set(id(r) for r in expired)
        self._items = [r for r in self._items if id(r) not in dead]
      return expired

  def head_age(self, now: float | None = None) -> float | None:
    """Seconds the oldest queued request has waited (None when empty)."""
    with self._lock:
      if not self._items:
        return None
      now = self.clock() if now is None else now
      return now - self._items[0].submitted_at

  def head_group_size(self) -> int:
    """How many queued requests share the oldest request's group key."""
    with self._lock:
      if not self._items:
        return 0
      key = self._items[0].group
      return sum(1 for r in self._items if r.group == key)

  def pop_group(self, max_batch: int) -> list[Request]:
    """Dequeue up to ``max_batch`` requests sharing the head's group key.

    FIFO across groups: the oldest request picks the group, and only
    requests in that group leave the queue (others keep their order).
    """
    with self._lock:
      if not self._items:
        return []
      key = self._items[0].group
      taken: list[Request] = []
      rest: list[Request] = []
      for r in self._items:
        if r.group == key and len(taken) < max_batch:
          taken.append(r)
        else:
          rest.append(r)
      self._items = rest
      return taken

"""The micro-batching serving engine.

Data path (docs/SERVING.md has the diagram)::

    submit(Request)                       # admission: bounded queue
      -> shape bucket (BucketPolicy)      # pad target for this n
      -> micro-batch (max-wait/max-batch) # group = (op-variant, bucket)
      -> AOT executable (AOTExecutableCache, plan-warmable)
      -> repro.kernels.dispatch           # backend resolved at trace time
      -> ServeResult (typed; sliced back to the request's n)

Everything per-request rides as traced arrays (values, true_n, eps, k,
trim, ...), so one executable per ``(op-variant, rows, bucket)`` cell
serves any parameter mix; the padding constructions in
:mod:`repro.serving.ops` make the bucket pads exact.

The engine is synchronous-first: ``step()`` advances one micro-batch and
is what the tests drive deterministically (with an injected clock);
``start()``/``stop()`` wrap the same step loop in a background thread
for the push-style API; ``serve()`` runs a whole request stream with
backpressure (the benchmark's throughput loop).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Iterable, Sequence

import jax
import numpy as np

from repro import plan as plan_mod
from repro.obs import metrics
from repro.serving.admission import (
    AdmissionQueue,
    Request,
    ServeResult,
    STATUS_ERROR,
    STATUS_OK,
    STATUS_SHED_DEADLINE,
    STATUS_SHED_QUEUE_FULL,
)
from repro.serving.aot_cache import AOTExecutableCache
from repro.serving.bucketing import BucketPolicy
from repro.serving.ops import (
    EXTRA_SCALAR,
    OpSpec,
    SERVING_OPS,
    bound_op,
    padded_op,
)

DTYPE = "float32"

#: Default extras for pad rows (true_n=1, eps=1): valid for every op.
_EXTRA_DEFAULTS = {"k": 1, "trim": 0}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
  """Tunables of one :class:`ServingEngine` instance."""

  ops: tuple[str, ...] = ("soft_rank/l2/desc", "soft_sort/l2/desc")
  min_bucket: int = 64
  max_bucket: int = 4096
  max_batch: int = 64
  max_wait_ms: float = 2.0
  queue_capacity: int = 1024
  default_deadline_ms: float | None = None
  aot_capacity: int = 256
  impl: str | None = None         # pin a backend; None = resolution chain
  use_plan_buckets: bool = True   # splice plan breakpoints into the ladder

  def __post_init__(self):
    for key in self.ops:
      if key not in SERVING_OPS:
        raise ValueError(f"unknown serving op {key!r}; expected keys from "
                         f"repro.serving.SERVING_OPS (e.g. "
                         f"{sorted(SERVING_OPS)[:4]} ...)")
    if self.max_batch < 1:
      raise ValueError("max_batch must be >= 1")


class ServingEngine:
  """Shape-bucketed dynamic batcher over the padded op family."""

  def __init__(self, config: EngineConfig | None = None, *,
               plan: "plan_mod.ExecutionPlan | None" = None,
               clock=time.monotonic):
    self.config = config or EngineConfig()
    self.plan = plan
    self.clock = clock
    if self.config.use_plan_buckets:
      self.policy = BucketPolicy.from_plan(
          plan, min_n=self.config.min_bucket, max_n=self.config.max_bucket,
          max_batch=self.config.max_batch)
    else:
      self.policy = BucketPolicy.pow2(
          self.config.min_bucket, self.config.max_bucket,
          self.config.max_batch)
    self.cache = AOTExecutableCache(self.config.aot_capacity)
    self.queue = AdmissionQueue(self.config.queue_capacity, clock=clock)
    self._step_lock = threading.Lock()
    self._backend_label: dict[tuple[str, int, int], str] = {}
    self._thread: threading.Thread | None = None
    self._running = False

  # -- AOT compilation ------------------------------------------------------

  def _backend_for(self, spec: OpSpec, rows: int, bucket_n: int) -> str:
    """Attribution label: the backend the plan chain resolves for this
    cell (the compiled executable embeds it at trace time)."""
    if self.config.impl is not None:
      return self.config.impl
    key = (spec.regularization, rows, bucket_n)
    label = self._backend_label.get(key)
    if label is None:
      cell = plan_mod.resolve_grid(
          "forward", ["isotonic"], [spec.regularization],
          [(rows, bucket_n)], platform=jax.default_backend(),
          plan=self.plan)
      label = cell[0]["backend"]
      self._backend_label[key] = label
    return label

  def _cell_key(self, spec: OpSpec, rows: int, bucket_n: int):
    backend = self._backend_for(spec, rows, bucket_n)
    return (spec.key, backend, rows, bucket_n, DTYPE)

  def _arg_structs(self, spec: OpSpec, rows: int, bucket_n: int):
    structs = [
        jax.ShapeDtypeStruct((rows, bucket_n), np.float32),  # values
        jax.ShapeDtypeStruct((rows,), np.int32),             # true_n
        jax.ShapeDtypeStruct((rows,), np.float32),           # eps
    ]
    for _, dtype, kind in spec.extras:
      shape = (rows,) if kind == EXTRA_SCALAR else (rows, bucket_n)
      structs.append(jax.ShapeDtypeStruct(shape, np.dtype(dtype)))
    return structs

  def _builder(self, spec: OpSpec, rows: int, bucket_n: int):
    def build():
      fn = jax.jit(bound_op(spec.key, self.config.impl, self.plan))
      return fn.lower(*self._arg_structs(spec, rows, bucket_n)).compile()
    return build

  def warmup(self, ops: Sequence[str] | None = None,
             sizes: Sequence[int] | None = None,
             row_sizes: Sequence[int] | None = None) -> int:
    """AOT-compile every (op, rows, bucket) cell the policy can route to.

    Enumeration comes from the bucket policy, which itself derives from
    the governing ExecutionPlan (``BucketPolicy.from_plan``) — so a
    plan-covered request stream hits zero ``aot_cache_miss`` afterwards.
    Returns the number of fresh compiles.
    """
    compiled = 0
    for key in (ops or self.config.ops):
      spec = padded_op(key)
      for bucket_n in (sizes or self.policy.sizes):
        for rows in (row_sizes or self.policy.row_sizes):
          if self.cache.warm(self._cell_key(spec, rows, bucket_n),
                             self._builder(spec, rows, bucket_n)):
            compiled += 1
    return compiled

  # -- admission ------------------------------------------------------------

  def submit(self, req: Request) -> Request:
    """Admit one request; always returns the handle with a typed outcome
    (possibly already finished as shed/error — never an exception for
    load conditions)."""
    now = self.clock()
    req.submitted_at = now
    deadline_ms = (req.deadline_ms if req.deadline_ms is not None
                   else self.config.default_deadline_ms)
    req.deadline_at = None if deadline_ms is None else now + deadline_ms / 1e3
    try:
      spec = padded_op(req.op)
      req.bucket_n = self.policy.bucket_for(req.n)
    except (KeyError, ValueError) as e:
      metrics.counter_inc("serving_shed", reason="invalid")
      req.finish(ServeResult(STATUS_ERROR, req.request_id, req.op, req.n,
                             detail=str(e)))
      return req
    if not self.queue.try_push(req):
      metrics.counter_inc("serving_shed", reason="queue_full")
      req.finish(ServeResult(STATUS_SHED_QUEUE_FULL, req.request_id, req.op,
                             req.n, bucket_n=req.bucket_n,
                             detail="admission queue at capacity"))
      return req
    metrics.counter_inc("serving_admit", op=spec.op)
    return req

  # -- the batcher ----------------------------------------------------------

  def step(self, flush: bool = False) -> list[ServeResult]:
    """Advance the engine: expire deadlines, then launch one micro-batch
    if the max-wait/max-batch policy says so (always, under ``flush``).

    Returns the results finished by this step (callers normally read
    per-request handles instead)."""
    with self._step_lock:
      now = self.clock()
      results: list[ServeResult] = []
      for req in self.queue.expire(now):
        metrics.counter_inc("serving_shed", reason="deadline")
        res = ServeResult(STATUS_SHED_DEADLINE, req.request_id, req.op,
                          req.n, bucket_n=req.bucket_n,
                          latency_us=(now - req.submitted_at) * 1e6,
                          detail="deadline expired in queue")
        req.finish(res)
        results.append(res)
      metrics.observe("serving_queue_depth", len(self.queue))
      head_age = self.queue.head_age(now)
      if head_age is None:
        return results
      due = (flush or head_age * 1e3 >= self.config.max_wait_ms
             or self.queue.head_group_size() >= self.config.max_batch)
      if not due:
        return results
      batch = self.queue.pop_group(self.config.max_batch)
      if batch:
        results.extend(self._execute(batch))
      return results

  def _execute(self, batch: list[Request]) -> list[ServeResult]:
    spec = padded_op(batch[0].op)
    bucket_n = batch[0].bucket_n
    m = len(batch)
    rows = self.policy.rows_for(m)
    values = np.zeros((rows, bucket_n), np.float32)
    true_n = np.ones((rows,), np.int32)
    eps = np.ones((rows,), np.float32)
    extras = []
    for name, dtype, kind in spec.extras:
      if kind == EXTRA_SCALAR:
        extras.append(np.full((rows,), _EXTRA_DEFAULTS.get(name, 0),
                              np.dtype(dtype)))
      else:
        extras.append(np.zeros((rows, bucket_n), np.dtype(dtype)))
    for i, req in enumerate(batch):
      n = req.n
      values[i, :n] = np.asarray(req.values, np.float32)
      true_n[i] = n
      eps[i] = req.eps
      for slot, (name, dtype, kind) in zip(extras, spec.extras):
        if name not in req.extras:
          continue
        if kind == EXTRA_SCALAR:
          slot[i] = req.extras[name]
        else:
          slot[i, :n] = np.asarray(req.extras[name], np.dtype(dtype))
    try:
      exe = self.cache.get(self._cell_key(spec, rows, bucket_n),
                           self._builder(spec, rows, bucket_n))
      out = np.asarray(jax.block_until_ready(exe(values, true_n, eps,
                                                 *extras)))
    except Exception as e:  # typed errors, not exceptions, per contract
      metrics.counter_inc("serving_error", op=spec.op)
      results = []
      for req in batch:
        res = ServeResult(STATUS_ERROR, req.request_id, req.op, req.n,
                          bucket_n=bucket_n, rows=rows,
                          detail=f"{type(e).__name__}: {e}")
        req.finish(res)
        results.append(res)
      return results
    done = self.clock()
    metrics.observe("serving_batch_occupancy", 100.0 * m / rows, op=spec.op)
    real = float(sum(r.n for r in batch))
    metrics.observe("serving_padding_waste",
                    100.0 * (1.0 - real / (rows * bucket_n)), op=spec.op)
    metrics.counter_inc("serving_batch_exec", op=spec.op)
    results = []
    for i, req in enumerate(batch):
      value = out[i, :req.n] if spec.output == "vector" else out[i].item()
      latency_us = (done - req.submitted_at) * 1e6
      metrics.observe("serving_latency_us", latency_us, op=spec.op)
      res = ServeResult(STATUS_OK, req.request_id, req.op, req.n,
                        value=value, latency_us=latency_us,
                        bucket_n=bucket_n, rows=rows)
      req.finish(res)
      results.append(res)
    return results

  def drain(self) -> list[ServeResult]:
    """Flush until the queue is empty (expiries included)."""
    results: list[ServeResult] = []
    while len(self.queue):
      results.extend(self.step(flush=True))
    return results

  def serve(self, requests: Iterable[Request], *,
            backpressure: bool = True) -> list[ServeResult]:
    """Run a whole request stream; returns results in submission order.

    With ``backpressure`` (default) a full queue makes the *caller* wait
    by stepping the engine instead of shedding — the benchmark's
    closed-loop throughput mode.  Without it, admission behaves exactly
    like ``submit`` (reject-on-full)."""
    handles = []
    for req in requests:
      if backpressure:
        while len(self.queue) >= self.queue.capacity:
          self.step(flush=True)
      handles.append(self.submit(req))
      self.step()
    self.drain()
    return [h.result(timeout=0.0) for h in handles]

  # -- background thread ----------------------------------------------------

  def start(self) -> None:
    """Run the step loop in a daemon thread (push-style serving)."""
    if self._thread is not None:
      return
    self._running = True
    tick = min(max(self.config.max_wait_ms / 4e3, 0.0002), 0.01)

    def loop():
      while self._running:
        if not self.step():
          time.sleep(tick)

    self._thread = threading.Thread(target=loop, name="repro-serving",
                                    daemon=True)
    self._thread.start()

  def stop(self, drain: bool = True) -> None:
    if self._thread is None:
      return
    self._running = False
    self._thread.join(timeout=10.0)
    self._thread = None
    if drain:
      self.drain()


# ---------------------------------------------------------------------------
# Synthetic traffic (bench, smoke, demos).
# ---------------------------------------------------------------------------


def synthetic_stream(num_requests: int, *, seed: int = 0,
                     ops: Sequence[str] = ("soft_rank/l2/desc",
                                           "soft_sort/l2/desc"),
                     n_min: int = 64, n_max: int = 4096,
                     deadline_ms: float | None = None) -> list[Request]:
  """A Zipf-ish mixed-size request stream (sizes skew small, heavy tail
  up to ``n_max``) over the given op variants."""
  rng = np.random.default_rng(seed)
  out = []
  for _ in range(num_requests):
    # u^2 skews the log-uniform draw toward small n (Zipf-flavored).
    u = rng.random() ** 2
    n = int(round(n_min * (n_max / n_min) ** u))
    n = int(np.clip(n, n_min, n_max))
    key = ops[int(rng.integers(len(ops)))]
    spec = padded_op(key)
    values = rng.standard_normal(n).astype(np.float32)
    extras: dict = {}
    for name, dtype, kind in spec.extras:
      if name == "k":
        extras["k"] = int(rng.integers(1, max(2, n // 4)))
      elif name == "trim":
        extras["trim"] = int(rng.integers(0, max(1, n // 4)))
      elif name == "target":
        extras["target"] = rng.permutation(n).astype(np.float32) + 1.0
      elif name == "w":
        extras["w"] = rng.standard_normal(n).astype(np.float32)
    out.append(Request(op=key, values=values,
                       eps=float(10 ** rng.uniform(-1.0, 0.5)),
                       extras=extras, deadline_ms=deadline_ms))
  return out

"""Deterministic, shardable, exactly-resumable synthetic token pipeline.

Stateless design: batch(step) is a pure function of (seed, step, host
slice), generated with a counter-based RNG (Philox).  Resume-after-restart
is therefore trivial (no iterator state in checkpoints — just the step),
and any host can regenerate any shard, which is what elastic restarts
need (a host taking over another's shard replays it bit-exactly).

The ``corrupt_fraction`` knob injects label noise into a random subset of
target tokens — the outlier source for the soft-LTS robust-training
example (paper §6.4 lifted to LM pretraining).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
  vocab_size: int
  global_batch: int
  seq_len: int
  seed: int = 0
  num_hosts: int = 1
  host_id: int = 0
  corrupt_fraction: float = 0.0
  num_codebooks: int = 0      # audio targets (B, S, K)
  d_model: int = 0            # frontend-stub embedding width
  frontend: str = "none"
  num_patches: int = 0


class TokenPipeline:
  """batch_at(step) -> dict of numpy arrays (host-local shard)."""

  def __init__(self, cfg: DataConfig):
    assert cfg.global_batch % cfg.num_hosts == 0
    self.cfg = cfg
    self.local_batch = cfg.global_batch // cfg.num_hosts

  def _rng(self, step: int, stream: int) -> np.random.Generator:
    c = self.cfg
    return np.random.Generator(np.random.Philox(
        key=c.seed, counter=[step, c.host_id, stream, 0]))

  def batch_at(self, step: int) -> dict[str, np.ndarray]:
    c = self.cfg
    b, s = self.local_batch, c.seq_len
    rng = self._rng(step, 0)
    out: dict[str, np.ndarray] = {}

    if c.frontend == "audio":
      out["embeds"] = rng.standard_normal(
          (b, s, c.d_model), dtype=np.float32)
      out["targets"] = rng.integers(
          0, c.vocab_size, (b, s, c.num_codebooks), dtype=np.int32)
    elif c.frontend == "vision":
      st = s - c.num_patches
      tokens = rng.integers(0, c.vocab_size, (b, st + 1), dtype=np.int32)
      out["tokens"] = tokens[:, :-1]
      out["image_embeds"] = rng.standard_normal(
          (b, c.num_patches, c.d_model), dtype=np.float32)
      out["targets"] = tokens[:, 1:].copy()
    else:
      # Markov-ish stream: correlated tokens so the loss actually decreases.
      base = rng.integers(0, c.vocab_size, (b, s + 1), dtype=np.int32)
      drift = rng.integers(0, 7, (b, s + 1), dtype=np.int32)
      tokens = (np.cumsum(drift, axis=1) + base // 7) % c.vocab_size
      out["tokens"] = tokens[:, :-1].astype(np.int32)
      out["targets"] = tokens[:, 1:].astype(np.int32).copy()

    if c.corrupt_fraction > 0 and "targets" in out:
      rng2 = self._rng(step, 1)
      mask = rng2.random(out["targets"].shape) < c.corrupt_fraction
      noise = rng2.integers(0, c.vocab_size, out["targets"].shape,
                            dtype=np.int32)
      out["targets"] = np.where(mask, noise, out["targets"])
      out["corrupt_mask"] = mask
    return out


def pipeline_for_arch(arch_cfg, global_batch: int, seq_len: int,
                      seed: int = 0, **kw) -> TokenPipeline:
  return TokenPipeline(DataConfig(
      vocab_size=arch_cfg.vocab_size,
      global_batch=global_batch,
      seq_len=seq_len,
      seed=seed,
      num_codebooks=arch_cfg.num_codebooks,
      d_model=arch_cfg.d_model,
      frontend=arch_cfg.frontend,
      num_patches=arch_cfg.num_patches,
      **kw,
  ))

"""Process-local metrics registry: counters + histograms with labels.

Design constraints (see ISSUE 6 / docs/BENCHMARKS.md):

* **Near-zero overhead when disabled.**  ``REPRO_METRICS=0`` (or ``false`` /
  ``off``) turns every recording call into a single predicate check and
  retains *no* state — not even auxiliary caches like the dispatch layer's
  seen-trace-key set (those register reset/disable hooks here).  Note the
  recording calls only ever run at Python trace time anyway; nothing in this
  module executes inside a jitted computation.
* **Deterministic flattening.**  A metric instance is identified by
  ``name{k=v,...}`` with label keys sorted, so snapshots are stable across
  runs and safely diffable in CI artifacts.
* **No dependencies.**  Stdlib only; importable from the dispatch layer
  without cycles.

The registry is process-global and thread-safe enough for the CPython uses
here (dict ops under the GIL); it is *not* a distributed metrics system —
artifacts snapshot it into ``BENCH_*.json`` instead.
"""

from __future__ import annotations

import math
import os
import threading
from typing import Callable

ENV_VAR = "REPRO_METRICS"

_FALSY = ("0", "false", "off", "no")

_lock = threading.Lock()
_counters: dict[str, int] = {}
_histograms: dict[str, dict] = {}
# None -> consult the environment on next call; True/False -> forced.
_enabled_override: bool | None = None
# Hooks run on reset() and on set_enabled(False): auxiliary state held by
# other modules (e.g. dispatch's trace-key cache) must also be dropped so
# "disabled mode records no state" holds globally.
_reset_hooks: list[Callable[[], None]] = []


def enabled() -> bool:
  """True if metrics recording is on (default; ``REPRO_METRICS=0`` opts out)."""
  if _enabled_override is not None:
    return _enabled_override
  return os.environ.get(ENV_VAR, "1").strip().lower() not in _FALSY


def set_enabled(on: bool | None) -> None:
  """Force metrics on/off programmatically; ``None`` defers to the env var.

  Turning metrics off also drops auxiliary state registered via
  ``on_reset`` so a disabled process holds no recorded state at all.
  """
  global _enabled_override
  _enabled_override = on
  if on is False:
    reset()


def on_reset(hook: Callable[[], None]) -> None:
  """Register a callback invoked by ``reset()`` (aux-state invalidation)."""
  _reset_hooks.append(hook)


def reset() -> None:
  """Clear every counter/histogram and all registered auxiliary state."""
  with _lock:
    _counters.clear()
    _histograms.clear()
  for hook in _reset_hooks:
    hook()


def _key(name: str, labels: dict) -> str:
  if not labels:
    return name
  inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
  return f"{name}{{{inner}}}"


def counter_inc(name: str, value: int = 1, /, **labels) -> None:
  """Increment counter ``name{labels}`` by ``value`` (no-op when disabled)."""
  if not enabled():
    return
  k = _key(name, labels)
  with _lock:
    _counters[k] = _counters.get(k, 0) + value


def counter_value(name: str, /, **labels) -> int:
  """Current value of a counter (0 if never incremented)."""
  return _counters.get(_key(name, labels), 0)


def observe(name: str, value: float, /, **labels) -> None:
  """Record ``value`` into histogram ``name{labels}`` (no-op when disabled).

  Histograms keep count/sum/min/max plus power-of-two bucket counts
  (bucket ``b`` counts values ``<= 2**b``), which is enough resolution for
  us/call timing trajectories without unbounded storage.
  """
  if not enabled():
    return
  k = _key(name, labels)
  with _lock:
    h = _histograms.get(k)
    if h is None:
      h = {"count": 0, "sum": 0.0, "min": math.inf, "max": -math.inf,
           "buckets": {}}
      _histograms[k] = h
    h["count"] += 1
    h["sum"] += float(value)
    h["min"] = min(h["min"], float(value))
    h["max"] = max(h["max"], float(value))
    b = pow2_bucket(value)
    h["buckets"][b] = h["buckets"].get(b, 0) + 1


def pow2_bucket(value: float) -> str:
  """Histogram bucket label: smallest power of two >= value (``<=2^k``)."""
  v = max(float(value), 0.0)
  if v <= 1.0:
    return "<=2^0"
  return f"<=2^{math.ceil(math.log2(v))}"


def shape_bucket(rows: int, n: int) -> str:
  """Stable low-cardinality label for a flattened (rows, n) problem shape.

  No commas (commas separate labels in flattened keys): e.g. ``r2^3_n2^7``
  for a batch of <=8 rows at n <= 128.
  """
  return f"r{pow2_bucket(rows)[2:]}_n{pow2_bucket(n)[2:]}"


def counters(prefix: str = "") -> dict[str, int]:
  """Flattened ``name{labels}`` -> value view (optionally prefix-filtered)."""
  with _lock:
    return {k: v for k, v in sorted(_counters.items())
            if k.startswith(prefix)}


def histograms(prefix: str = "") -> dict[str, dict]:
  """Flattened histogram view; ``sum``/``min``/``max`` are JSON-safe floats."""
  out = {}
  with _lock:
    for k in sorted(_histograms):
      if not k.startswith(prefix):
        continue
      h = _histograms[k]
      out[k] = {
          "count": h["count"],
          "sum": h["sum"],
          "min": h["min"] if h["count"] else None,
          "max": h["max"] if h["count"] else None,
          "buckets": dict(sorted(h["buckets"].items())),
      }
  return out


def snapshot() -> dict:
  """JSON-serializable snapshot of the whole registry (for artifacts)."""
  return {"enabled": enabled(), "counters": counters(),
          "histograms": histograms()}

"""CLI: validate BENCH artifacts — ``python -m repro.obs BENCH_*.json``."""

from repro.obs.artifacts import main

raise SystemExit(main())

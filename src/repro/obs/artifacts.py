"""Structured ``BENCH_*.json`` artifacts: one schema, one emitter, one gate.

Schema ``repro.bench/v1`` (documented in docs/BENCHMARKS.md):

.. code-block:: json

  {
    "schema": "repro.bench/v1",
    "meta": {
      "git_sha": "<40-hex or 'unknown'>",
      "platform": "cpu|gpu|tpu",
      "jax": "<version>",
      "smoke": false,            // plus free-form extras (argv, arch, ...)
    },
    "metrics": { "enabled": true, "counters": {...}, "histograms": {...} },
    "results": [
      { "name": "backend_sweep/l2/lax/n=100/b=8",
        "op": "soft_rank", "regularization": "l2", "backend": "lax",
        "n": 100, "batch": 8, "fwd_us": 2051.3, "fwd_bwd_us": 3380.2 },
      { "name": "backend_sweep/l2/minimax/n=10000/b=256",
        "skipped": "minimax needs batch*n^2 = 2.56e+10 f32 elems" }
    ]
  }

Every producer (``benchmarks/run.py``, ``repro.launch.train``,
``repro.launch.serve``) funnels through :func:`write_bench_artifact`, and CI
runs ``python -m repro.obs.artifacts BENCH_*.json`` after the bench smoke —
an artifact that fails :func:`validate_bench_payload` fails the build, so
the uploaded trajectory stays machine-readable across PRs.

Result contract: each record needs a string ``name`` and then *either* a
string ``skipped`` reason *or* at least one finite, non-negative ``*_us``
timing field.  Extra keys (shape grid, derived stats) are free-form.
"""

from __future__ import annotations

import json
import subprocess
import sys
import time

import jax

from repro.obs import metrics

SCHEMA_VERSION = "repro.bench/v1"

_META_REQUIRED = ("git_sha", "platform", "jax")


def git_sha() -> str:
  """Current commit sha, or 'unknown' outside a git checkout."""
  try:
    out = subprocess.run(
        ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
        timeout=10, check=False)
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else "unknown"
  except (OSError, subprocess.SubprocessError):
    return "unknown"


def collect_meta(**extra) -> dict:
  """Standard provenance block: sha, platform, versions + caller extras."""
  meta = {
      "git_sha": git_sha(),
      "platform": jax.default_backend(),
      "jax": jax.__version__,
      "python": sys.version.split()[0],
      "unix_time": int(time.time()),
  }
  meta.update(extra)
  return meta


def bench_payload(results: list[dict], meta: dict | None = None) -> dict:
  """Assemble a schema-v1 payload: results + meta + live metrics snapshot."""
  return {
      "schema": SCHEMA_VERSION,
      "meta": meta if meta is not None else collect_meta(),
      "metrics": metrics.snapshot(),
      "results": list(results),
  }


def write_bench_artifact(path: str, results: list[dict],
                         meta: dict | None = None) -> dict:
  """Validate and write a ``BENCH_*.json`` artifact; returns the payload.

  Emitting an invalid artifact raises immediately — producers fail at the
  source instead of CI discovering a malformed upload later.
  """
  payload = bench_payload(results, meta)
  errors = validate_bench_payload(payload)
  if errors:
    raise ValueError(f"refusing to write invalid {path}: {errors}")
  with open(path, "w") as f:
    json.dump(payload, f, indent=2, sort_keys=True)
  print(f"wrote {path} ({len(payload['results'])} results)")
  return payload


def _finite_number(v) -> bool:
  return (isinstance(v, (int, float)) and not isinstance(v, bool)
          and v == v and abs(v) != float("inf"))


def validate_bench_payload(payload) -> list[str]:
  """Schema-v1 check; returns a list of human-readable errors ([] = valid)."""
  errs: list[str] = []
  if not isinstance(payload, dict):
    return [f"payload must be an object, got {type(payload).__name__}"]
  if payload.get("schema") != SCHEMA_VERSION:
    errs.append(f"schema must be {SCHEMA_VERSION!r}, "
                f"got {payload.get('schema')!r}")

  meta = payload.get("meta")
  if not isinstance(meta, dict):
    errs.append("meta must be an object")
  else:
    for k in _META_REQUIRED:
      if not isinstance(meta.get(k), str) or not meta[k]:
        errs.append(f"meta.{k} must be a non-empty string")

  mx = payload.get("metrics")
  if not isinstance(mx, dict):
    errs.append("metrics must be an object")
  else:
    if not isinstance(mx.get("counters"), dict):
      errs.append("metrics.counters must be an object")
    elif not all(isinstance(v, int) for v in mx["counters"].values()):
      errs.append("metrics.counters values must be integers")
    if not isinstance(mx.get("histograms"), dict):
      errs.append("metrics.histograms must be an object")

  results = payload.get("results")
  if not isinstance(results, list):
    errs.append("results must be a list")
    return errs
  for i, rec in enumerate(results):
    where = f"results[{i}]"
    if not isinstance(rec, dict):
      errs.append(f"{where} must be an object")
      continue
    if not isinstance(rec.get("name"), str) or not rec["name"]:
      errs.append(f"{where}.name must be a non-empty string")
    if "skipped" in rec:
      if not isinstance(rec["skipped"], str) or not rec["skipped"]:
        errs.append(f"{where}.skipped must be a non-empty reason string")
      continue
    timing_keys = [k for k in rec if k.endswith("_us")]
    if not timing_keys:
      errs.append(f"{where} needs a '*_us' timing field or a "
                  f"'skipped' reason (name={rec.get('name')!r})")
    for k in timing_keys:
      if not _finite_number(rec[k]) or rec[k] < 0:
        errs.append(f"{where}.{k} must be a finite non-negative number, "
                    f"got {rec[k]!r}")
  return errs


def validate_file(path: str) -> list[str]:
  """Validate one artifact file; unreadable/unparsable counts as invalid."""
  try:
    with open(path) as f:
      payload = json.load(f)
  except (OSError, json.JSONDecodeError) as e:
    return [f"{path}: cannot load: {e}"]
  return [f"{path}: {e}" for e in validate_bench_payload(payload)]


def main(argv: list[str] | None = None) -> int:
  """CLI gate: ``python -m repro.obs.artifacts BENCH_*.json`` (CI smoke)."""
  paths = sys.argv[1:] if argv is None else argv
  if not paths:
    print("usage: python -m repro.obs.artifacts BENCH_*.json", file=sys.stderr)
    return 2
  failures = 0
  for path in paths:
    errors = validate_file(path)
    if errors:
      failures += 1
      for e in errors:
        print(f"INVALID {e}", file=sys.stderr)
    else:
      print(f"ok {path}")
  return 1 if failures else 0


if __name__ == "__main__":
  raise SystemExit(main())

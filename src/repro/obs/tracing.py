"""Attribution scopes: name-stack + profiler annotations for dispatch.

Two complementary mechanisms, used together by
``repro.kernels.dispatch.dispatch``:

* ``backend_scope`` — a ``jax.named_scope`` pushed around the backend
  forward call at *trace* time.  The scope name lands on every primitive
  the backend emits, so jaxprs, lowered StableHLO and compiled-HLO
  ``op_name`` metadata (and therefore ``jax.profiler`` / XLA trace viewers)
  all attribute kernel time to ``repro_<op>_<reg>_<backend>`` instead of an
  anonymous soup of ``while``/``scatter`` ops.
* ``trace_annotation`` — a host-side ``jax.profiler.TraceAnnotation``
  (no-op fallback if the profiler API is unavailable) for *eager* wall
  regions: benchmark timing loops, train-step walls, serve prefill/decode.

Scope names are ``[a-z0-9_]`` only: every consumer (HLO metadata, TensorBoard
trace viewer, pprof) treats ``/`` and ``=`` as structure.
"""

from __future__ import annotations

import contextlib
import re

import jax

_SANITIZE = re.compile(r"[^a-z0-9_]+")


def _clean(part: str) -> str:
  return _SANITIZE.sub("_", str(part).lower()).strip("_") or "unknown"


def scope_name(op: str, regularization: str, backend: str) -> str:
  """Canonical name-stack entry for a dispatched backend call."""
  return f"repro_{_clean(op)}_{_clean(regularization)}_{_clean(backend)}"


def backend_scope(op: str, regularization: str, backend: str):
  """``jax.named_scope`` labeling every primitive a backend emits."""
  return jax.named_scope(scope_name(op, regularization, backend))


def trace_annotation(name: str):
  """Host-side profiler annotation (eager regions); nullcontext fallback."""
  annotation = getattr(jax.profiler, "TraceAnnotation", None)
  if annotation is None:  # very old jax; keep the API total
    return contextlib.nullcontext()
  return annotation(name)

"""Wall-clock timing: the one implementation the benchmarks and drivers use.

All timings are *eager* ``block_until_ready`` walls — device work is forced
to completion inside the measured region, so the numbers are end-to-end
per-call latencies, not async-dispatch artifacts.  When metrics are enabled
each measurement is also recorded into the ``obs`` histogram registry so
artifacts carry the full distribution, not just the median.
"""

from __future__ import annotations

import math
import time
from typing import Callable

import jax

from repro.obs import metrics
from repro.obs.tracing import trace_annotation


def timed(fn: Callable, *args) -> tuple[object, float]:
  """Run ``fn(*args)``, block until device-complete; (result, seconds)."""
  t0 = time.perf_counter()
  out = jax.block_until_ready(fn(*args))
  return out, time.perf_counter() - t0


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            name: str | None = None) -> float:
  """Median wall time per call in microseconds (jit-compiled ``fn``).

  ``warmup`` calls (compilation + cache effects) are excluded from the
  measurement.  When ``name`` is given and metrics are enabled, every
  measured iteration is observed into histogram ``bench_us{name=...}``.
  """
  for _ in range(warmup):
    jax.block_until_ready(fn(*args))
  times = []
  with trace_annotation(f"repro_bench_{name}" if name else "repro_bench"):
    for _ in range(iters):
      _, dt = timed(fn, *args)
      times.append(dt)
  if name is not None:
    for dt in times:
      metrics.observe("bench_us", dt * 1e6, name=name)
  times.sort()
  return times[len(times) // 2] * 1e6


def percentiles(samples, qs=(50, 95, 99)) -> tuple[float, ...]:
  """Nearest-rank percentiles of a sample list (sorted or not).

  The serving benchmarks report p50/p95/p99 request latencies with this
  — nearest-rank (no interpolation) so the values are actual observed
  latencies.  Returns one float per ``q``; empty input gives zeros.
  """
  if not samples:
    return tuple(0.0 for _ in qs)
  ordered = sorted(samples)
  out = []
  for q in qs:
    rank = max(1, math.ceil(q / 100.0 * len(ordered)))
    out.append(float(ordered[min(rank, len(ordered)) - 1]))
  return tuple(out)


class wall_timer:
  """Context manager: ``with wall_timer() as t: ...; t.seconds / t.us``."""

  def __enter__(self):
    self._t0 = time.perf_counter()
    self.seconds = 0.0
    return self

  def __exit__(self, *exc):
    self.seconds = time.perf_counter() - self._t0
    return False

  @property
  def us(self) -> float:
    return self.seconds * 1e6

"""Observability for the dispatch layer: metrics, tracing, timing, artifacts.

This package is the single place the repo records *what actually ran*:
which backend served each ``(op, regularization)`` dispatch, at what shapes,
how often jit re-traced, and how long benchmarked calls took.  It exists
because the paper's headline claim is performance (O(n log n) soft
sorting/ranking, "an order of magnitude faster" — Blondel et al., 2020) and
an unverifiable claim is not a reproduction.

Modules
-------
``repro.obs.metrics``
    Process-local counters and histograms, keyed by name + labels.  Gated
    by ``REPRO_METRICS`` (any value but ``0``/``false``/``off`` enables;
    default enabled).  When disabled every recording call is a constant-time
    no-op and no state is retained.
``repro.obs.tracing``
    ``jax.named_scope`` wrappers so dispatched backend kernels are
    attributable in jaxprs, HLO and ``jax.profiler`` traces, plus host-side
    profiler annotations for eager timing regions.
``repro.obs.timing``
    Wall-clock timing helpers (``block_until_ready`` walls, median
    us/call) shared by the benchmark harness and the launch drivers.
``repro.obs.artifacts``
    The one structured-JSON ``BENCH_*.json`` emitter + schema validator
    used by ``benchmarks/run.py``, ``repro.launch.train`` and
    ``repro.launch.serve`` (schema ``repro.bench/v1``; see
    docs/BENCHMARKS.md).  ``python -m repro.obs.artifacts FILE...``
    validates artifacts and is what CI gates the bench smoke on.

Layering: ``repro.obs`` imports only jax/stdlib — never ``repro.core`` or
``repro.kernels`` — so the dispatch layer can depend on it without cycles.
"""

from repro.obs import artifacts, metrics, timing, tracing
from repro.obs.artifacts import (
    SCHEMA_VERSION,
    bench_payload,
    collect_meta,
    validate_bench_payload,
    write_bench_artifact,
)
from repro.obs.metrics import (
    counter_inc,
    counters,
    enabled,
    histograms,
    observe,
    reset,
    set_enabled,
    snapshot,
)
from repro.obs.timing import time_fn, timed
from repro.obs.tracing import backend_scope, scope_name, trace_annotation

__all__ = [
    "SCHEMA_VERSION",
    "artifacts",
    "backend_scope",
    "bench_payload",
    "collect_meta",
    "counter_inc",
    "counters",
    "enabled",
    "histograms",
    "metrics",
    "observe",
    "reset",
    "scope_name",
    "set_enabled",
    "snapshot",
    "time_fn",
    "timed",
    "timing",
    "trace_annotation",
    "tracing",
    "validate_bench_payload",
    "write_bench_artifact",
]

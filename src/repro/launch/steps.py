"""Jit-able train / prefill / decode step builders.

These are the functions the dry-run lowers and the trainer/server drive.
All distribution is expressed through in/out shardings + the activation
constraints inside the model code; the steps themselves are mesh-agnostic.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.losses import soft_trimmed_token_loss
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compression import ef_int8_roundtrip

Array = jax.Array


def loss_from_batch(cfg, params, batch) -> tuple[Array, dict[str, Array]]:
  with jax.named_scope("repro_forward_train"):
    token_losses, aux = T.forward_train(cfg, params, batch)
  if cfg.loss_trim_fraction > 0:
    # Paper §6.4 at LM scale: soft least-trimmed-squares over per-token
    # losses, applied per sequence (bounded PAV length; DESIGN.md §4).
    with jax.named_scope("repro_soft_lts_loss"):
      loss = jnp.mean(soft_trimmed_token_loss(
          token_losses.reshape(token_losses.shape[0], -1),
          cfg.loss_trim_fraction, cfg.loss_trim_eps))
  else:
    loss = jnp.mean(token_losses)
  total = loss + 0.01 * aux
  return total, {"loss": loss, "aux_loss": aux}


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *,
                    lr_schedule=None, compress_grads: bool = False):
  """(params, opt_state, batch) -> (params, opt_state, metrics).

  Gradient accumulation: the global batch is split into ``cfg.grad_accum``
  microbatches scanned sequentially (activation memory / accum trade);
  grads are averaged in f32.
  """

  def grads_of(params, batch):
    return jax.value_and_grad(
        lambda p: loss_from_batch(cfg, p, batch), has_aux=True)(params)

  def train_step(params, opt_state, batch):
    accum = cfg.grad_accum
    if accum > 1:
      def micro(mb):
        return jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            mb)

      mbatches = micro(batch)

      def body(carry, mb):
        gsum, lsum = carry
        (_, metrics), g = grads_of(params, mb)
        gsum = jax.tree.map(
            lambda a, b: a + b.astype(a.dtype), gsum, g)
        return (gsum, lsum + metrics["loss"]), None

      acc_dt = jnp.dtype(getattr(cfg, "grad_accum_dtype", "float32"))
      gzero = jax.tree.map(
          lambda p: jnp.zeros(p.shape, acc_dt), params)
      (gsum, lsum), _ = jax.lax.scan(
          body, (gzero, jnp.zeros((), jnp.float32)), mbatches)
      grads = jax.tree.map(lambda g: (g / accum).astype(cfg.dtype), gsum)
      metrics = {"loss": lsum / accum,
                 "aux_loss": jnp.zeros((), jnp.float32)}
    else:
      (_, metrics), grads = grads_of(params, batch)

    if compress_grads:
      # int8 + error feedback wire format (cross-pod reduction model).
      grads, new_resid = ef_int8_roundtrip(grads, opt_state["ef_residual"])

    lr_scale = (lr_schedule(opt_state["adam"]["step"])
                if lr_schedule else 1.0)
    with jax.named_scope("repro_optimizer_update"):
      new_params, new_adam, opt_metrics = adamw.update(
          opt_cfg, grads, opt_state["adam"], params, lr_scale)
    new_opt = {"adam": new_adam}
    if compress_grads:
      new_opt["ef_residual"] = new_resid
    metrics = {**metrics, **opt_metrics}
    return new_params, new_opt, metrics

  return train_step


def init_opt_state(cfg, opt_cfg, params, *, compress_grads: bool = False):
  state = {"adam": adamw.init(opt_cfg, params)}
  if compress_grads:
    state["ef_residual"] = jax.tree.map(
        lambda p: jnp.zeros(p.shape, p.dtype), params)
  return state


def make_prefill_step(cfg, max_len: int | None = None):
  def prefill(params, batch):
    s = (batch["embeds"].shape[1] if cfg.frontend == "audio"
         else batch["tokens"].shape[1] + (
             cfg.num_patches if cfg.frontend == "vision" else 0))
    return T.forward_prefill(cfg, params, batch, max_len or s)
  return prefill


def make_decode_step(cfg):
  def decode(params, caches, inputs, pos):
    return T.forward_decode(cfg, params, caches, inputs, pos)
  return decode

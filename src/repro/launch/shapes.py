"""Assigned input-shape cells and ShapeDtypeStruct stand-ins.

Four shapes per LM architecture (40 cells total):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> serve_prefill
  decode_32k   seq 32768,  global_batch 128   -> serve_decode (1 new token)
  long_500k    seq 524288, global_batch 1     -> serve_decode; only for
               sub-quadratic archs (cfg.supports_long_context), others are
               recorded as skipped (DESIGN.md §6).

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no host or
device allocation ever happens for the full-size cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
  name: str
  seq_len: int
  global_batch: int
  kind: str                  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg, shape: ShapeCell) -> tuple[bool, str]:
  if shape.name == "long_500k" and not cfg.supports_long_context:
    return False, ("pure full-attention arch: 500k-token decode needs "
                   "sub-quadratic attention (skip per assignment)")
  return True, ""


def batch_specs(cfg, shape: ShapeCell) -> dict[str, Any]:
  """ShapeDtypeStructs for the train/prefill batch dict."""
  b, s = shape.global_batch, shape.seq_len
  i32 = jnp.int32
  if cfg.frontend == "audio":
    specs = {"embeds": SDS((b, s, cfg.d_model), jnp.float32)}
    if shape.kind == "train":
      specs["targets"] = SDS((b, s, cfg.num_codebooks), i32)
    return specs
  if cfg.frontend == "vision":
    st = s - cfg.num_patches
    specs = {
        "tokens": SDS((b, st), i32),
        "image_embeds": SDS((b, cfg.num_patches, cfg.d_model), jnp.float32),
    }
    if shape.kind == "train":
      specs["targets"] = SDS((b, st), i32)
    return specs
  specs = {"tokens": SDS((b, s), i32)}
  if shape.kind == "train":
    specs["targets"] = SDS((b, s), i32)
  return specs


def decode_token_specs(cfg, shape: ShapeCell) -> Any:
  b = shape.global_batch
  if cfg.frontend == "audio":
    return SDS((b, cfg.d_model), jnp.float32)
  return SDS((b,), jnp.int32)


def cache_specs(cfg, shape: ShapeCell) -> Any:
  """ShapeDtypeStruct pytree for the decode cache at seq_len fill."""
  from repro.models import transformer as T
  return jax.eval_shape(
      lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod), constructs fully-sharded train/prefill/decode steps from
ShapeDtypeStruct stand-ins (no allocation), compiles the SPMD program, and
records memory analysis + XLA cost analysis + the while-aware HLO cost
parse + roofline terms into experiments/dryrun/<cell>.json.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Variants (hillclimbing knobs) apply config overrides and tag the output:
  --set seq_shard_activations=True --set q_chunk=1024 --tag spq1024
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze_text
from repro.analysis.roofline import (
    count_active_params, model_flops, roofline_terms)
from repro.configs.base import all_assigned, get_config
from repro.launch import shapes as SH
from repro.launch import steps as ST
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw
from repro.sharding import specs as SP

DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "../../../experiments/dryrun")


def _named(mesh, spec_tree):
  return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                      is_leaf=lambda x: isinstance(x, P))


def parse_overrides(pairs):
  out = {}
  for pair in pairs or []:
    k, v = pair.split("=", 1)
    for cast in (int, float):
      try:
        out[k] = cast(v)
        break
      except ValueError:
        continue
    else:
      if v in ("True", "False"):
        out[k] = v == "True"
      else:
        out[k] = v
  return out


def lower_cell(cfg, cell, mesh):
  """Returns (lowered, aux_info)."""
  rules = SP.ShardingRules(
      mesh,
      data_axes=data_axes_of(mesh),
      model_axis="model",
      seq_shard_activations=cfg.seq_shard_activations,
      fsdp=cfg.fsdp,
  )
  key = jax.random.PRNGKey(0)
  params_shape = jax.eval_shape(lambda: T.init_params(cfg, key))
  pspecs = SP.param_specs_tree(rules, params_shape)
  pshard = _named(mesh, pspecs)
  info = {}

  with mesh, SP.use_rules(rules):
    if cell.kind == "train":
      opt_cfg = adamw.AdamWConfig(
          moment_dtype="bfloat16" if cfg.fsdp else "float32")
      opt_shape = jax.eval_shape(
          lambda p: ST.init_opt_state(cfg, opt_cfg, p), params_shape)
      ospecs = SP.opt_state_specs_tree(rules, opt_shape, pspecs)
      oshard = _named(mesh, ospecs)
      batch = SH.batch_specs(cfg, cell)
      bspecs = SP.batch_specs_tree(rules, batch)
      bshard = _named(mesh, bspecs)
      step = ST.make_train_step(cfg, opt_cfg)
      jitted = jax.jit(
          step,
          in_shardings=(pshard, oshard, bshard),
          out_shardings=(pshard, oshard, None),
          donate_argnums=(0, 1),
      )
      lowered = jitted.lower(params_shape, opt_shape, batch)
    elif cell.kind == "prefill":
      batch = SH.batch_specs(cfg, cell)
      bspecs = SP.batch_specs_tree(rules, batch)
      bshard = _named(mesh, bspecs)
      step = ST.make_prefill_step(cfg)
      jitted = jax.jit(step, in_shardings=(pshard, bshard))
      lowered = jitted.lower(params_shape, batch)
    else:  # decode
      caches = SH.cache_specs(cfg, cell)
      cspecs = SP.cache_specs_tree(rules, caches)
      cshard = _named(mesh, cspecs)
      tok = SH.decode_token_specs(cfg, cell)
      tok_spec = NamedSharding(
          mesh, rules.spec(tok.shape, (rules.data_axes,) + (None,) *
                           (len(tok.shape) - 1)))
      pos = jax.ShapeDtypeStruct((), jnp.int32)
      step = ST.make_decode_step(cfg)
      jitted = jax.jit(
          step,
          in_shardings=(pshard, cshard, tok_spec, NamedSharding(mesh, P())),
          out_shardings=(None, cshard),
          donate_argnums=(1,),
      )
      lowered = jitted.lower(params_shape, caches, tok, pos)

  total, active = count_active_params(cfg, params_shape)
  info["params_total"] = total
  info["params_active"] = active
  return lowered, info


def run_cell(arch, shape_name, multi_pod, overrides, outdir, force=False,
             tag="", keep_hlo=False):
  mesh_name = "multi" if multi_pod else "single"
  cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
  os.makedirs(outdir, exist_ok=True)
  path = os.path.join(outdir, cell_id + ".json")
  if os.path.exists(path) and not force:
    print(f"[skip] {cell_id} (cached)")
    return json.load(open(path))

  cfg = get_config(arch)
  if overrides:
    cfg = dataclasses.replace(cfg, **overrides)
  cell = SH.SHAPES[shape_name]
  ok, why = SH.cell_applicable(cfg, cell)
  record = {
      "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
      "overrides": overrides or {},
  }
  if not ok:
    record.update({"status": "skipped", "reason": why})
    json.dump(record, open(path, "w"), indent=1)
    print(f"[skip] {cell_id}: {why}")
    return record

  mesh = make_production_mesh(multi_pod=multi_pod)
  n_dev = mesh.size
  t0 = time.time()
  try:
    lowered, info = lower_cell(cfg, cell, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_estimate_bytes": (mem.argument_size_in_bytes +
                                mem.output_size_in_bytes +
                                mem.temp_size_in_bytes -
                                mem.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    parsed = analyze_text(hlo_text)
    mf = model_flops(cfg, jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0))), cell)
    roof = roofline_terms(parsed, n_dev, mf)

    record.update({
        "status": "ok",
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "params_total": info["params_total"],
        "params_active": info["params_active"],
        "memory": mem_rec,
        "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))},
        "hlo_parsed": parsed,
        "roofline": roof,
    })
    if keep_hlo:
      hlo_path = os.path.join(outdir, cell_id + ".hlo.txt")
      with open(hlo_path, "w") as f:
        f.write(hlo_text)
      record["hlo_path"] = hlo_path
    print(f"[ok]   {cell_id}: compile {t_compile:.0f}s, "
          f"dominant={roof['dominant']} ({roof['bound_s']*1e3:.2f} ms), "
          f"roofline_frac={roof['roofline_fraction']:.3f}, "
          f"mem/dev={mem_rec['peak_estimate_bytes']/2**30:.2f} GiB")
  except Exception as e:  # record failures — they are bugs to fix
    record.update({"status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()})
    print(f"[FAIL] {cell_id}: {e}")
  json.dump(record, open(path, "w"), indent=1)
  return record


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default=None)
  ap.add_argument("--shape", default=None, choices=list(SH.SHAPES) + [None])
  ap.add_argument("--mesh", default="single",
                  choices=["single", "multi", "both"])
  ap.add_argument("--all", action="store_true")
  ap.add_argument("--force", action="store_true")
  ap.add_argument("--keep-hlo", action="store_true")
  ap.add_argument("--out", default=DEFAULT_OUT)
  ap.add_argument("--tag", default="")
  ap.add_argument("--set", action="append", dest="overrides",
                  help="config override key=value (repeatable)")
  args = ap.parse_args()

  archs = all_assigned() if (args.all or not args.arch) else [args.arch]
  shapes = list(SH.SHAPES) if (args.all or not args.shape) else [args.shape]
  meshes = {"single": [False], "multi": [True],
            "both": [False, True]}[args.mesh]
  overrides = parse_overrides(args.overrides)

  n_fail = 0
  for arch in archs:
    for shape in shapes:
      for multi in meshes:
        rec = run_cell(arch, shape, multi, overrides, args.out,
                       force=args.force, tag=args.tag,
                       keep_hlo=args.keep_hlo)
        n_fail += rec.get("status") == "error"
  raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
  main()

"""End-to-end training driver with fault tolerance.

Features (designed for 1000+ node operation, exercised here on CPU):
  * checkpoint/restart: atomic async checkpoints every N steps; on start,
    auto-resume from the latest checkpoint (data pipeline is stateless, so
    resume = restore params/opt + continue from step);
  * preemption handling: SIGTERM/SIGINT trigger a final synchronous
    checkpoint before exit (the standard TPU-preemption protocol);
  * straggler mitigation: per-step deadline tracking — steps slower than
    ``straggler_factor`` x the rolling median are logged and counted; the
    hook is where a real fleet controller would re-shard or evict (on a
    single host we record + expose the metric);
  * elastic restart: checkpoints are mesh-independent (gathered arrays) —
    restoring onto a different mesh shape re-shards via the in_shardings
    of the restored step (see repro/checkpoint/checkpointer.py);
  * paper integration: ``--trim-frac`` enables the soft-LTS robust token
    loss; ``--router soft_topk`` is the projection router (MoE archs);
    ``--compress-grads`` turns on int8+error-feedback gradient exchange.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import plan as repro_plan
from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import get_config
from repro.data.pipeline import pipeline_for_arch
from repro.launch import steps as ST
from repro.launch.dryrun import parse_overrides
from repro.models import transformer as T
from repro.obs import artifacts as obs_artifacts
from repro.obs import metrics as obs_metrics
from repro.obs.tracing import trace_annotation
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup


@dataclasses.dataclass
class TrainerState:
  params: object
  opt_state: object
  step: int


class Trainer:

  def __init__(self, cfg, opt_cfg, *, batch: int, seq: int,
               ckpt_dir: str | None, ckpt_every: int = 50,
               compress_grads: bool = False, total_steps: int = 1000,
               corrupt_fraction: float = 0.0, seed: int = 0):
    self.cfg = cfg
    self.opt_cfg = opt_cfg
    self.pipeline = pipeline_for_arch(
        cfg, batch, seq, seed=seed, corrupt_fraction=corrupt_fraction)
    self.ckpt_dir = ckpt_dir
    self.ckpt_every = ckpt_every
    self.async_ckpt = (ckpt.AsyncCheckpointer(ckpt_dir)
                       if ckpt_dir else None)
    self.total_steps = total_steps
    sched = lambda s: cosine_with_warmup(
        s, warmup=min(100, total_steps // 10 + 1), total=total_steps)
    self.train_step = jax.jit(ST.make_train_step(
        cfg, opt_cfg, lr_schedule=sched, compress_grads=compress_grads))
    self.compress_grads = compress_grads
    self._preempted = False
    self._step_times: list[float] = []
    self.straggler_factor = 2.0
    self.straggler_events = 0

  # -- lifecycle ----------------------------------------------------------

  def init_or_restore(self) -> TrainerState:
    key = jax.random.PRNGKey(0)
    params = T.init_params(self.cfg, key)
    opt_state = ST.init_opt_state(self.cfg, self.opt_cfg, params,
                                  compress_grads=self.compress_grads)
    state = TrainerState(params, opt_state, 0)
    if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
      tree = {"params": params, "opt": opt_state}
      restored, meta = ckpt.restore(self.ckpt_dir, tree)
      state = TrainerState(restored["params"], restored["opt"],
                           int(meta["step"]))
      print(f"[train] resumed from step {state.step}")
    return state

  def install_preemption_handler(self):
    def handler(signum, frame):
      print(f"[train] caught signal {signum}: checkpoint-and-exit")
      self._preempted = True
    signal.signal(signal.SIGTERM, handler)
    signal.signal(signal.SIGINT, handler)

  def maybe_flag_straggler(self, dt: float):
    self._step_times.append(dt)
    window = self._step_times[-32:]
    if len(window) >= 8:
      med = statistics.median(window)
      if dt > self.straggler_factor * med:
        self.straggler_events += 1
        print(f"[train] straggler step: {dt*1e3:.0f} ms vs median "
              f"{med*1e3:.0f} ms (event #{self.straggler_events})")

  # -- main loop ----------------------------------------------------------

  def run(self, state: TrainerState, num_steps: int):
    metrics = {}
    for step in range(state.step, min(state.step + num_steps,
                                      self.total_steps)):
      if self._preempted:
        break
      batch = {k: jnp.asarray(v)
               for k, v in self.pipeline.batch_at(step).items()
               if k != "corrupt_mask"}
      t0 = time.time()
      with trace_annotation("repro_train_step"):
        state.params, state.opt_state, metrics = self.train_step(
            state.params, state.opt_state, batch)
        jax.block_until_ready(metrics["loss"])
      dt = time.time() - t0
      obs_metrics.observe("train_step_us", dt * 1e6)
      self.maybe_flag_straggler(dt)
      state.step = step + 1
      if step % 10 == 0 or step == state.step - 1:
        print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} ({dt*1e3:.0f} ms)")
      if self.async_ckpt and state.step % self.ckpt_every == 0:
        self.async_ckpt.save(
            state.step, {"params": state.params, "opt": state.opt_state},
            {"step": state.step})
    # final (synchronous) checkpoint — also the preemption path
    if self.async_ckpt:
      self.async_ckpt.wait()
      ckpt.save(self.ckpt_dir, state.step,
                {"params": state.params, "opt": state.opt_state},
                {"step": state.step})
    return state, metrics

  def bench_results(self, final_metrics) -> list[dict]:
    """Structured run summary for the schema-v1 bench artifact."""
    times = sorted(self._step_times)
    if not times:
      return []
    median = times[len(times) // 2]
    return [{
        "name": "train/step",
        "median_step_us": median * 1e6,
        "p90_step_us": times[min(len(times) - 1,
                                 int(len(times) * 0.9))] * 1e6,
        "steps_timed": len(times),
        "straggler_events": self.straggler_events,
        "final_loss": float(final_metrics.get("loss", float("nan"))),
    }]


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", required=True)
  ap.add_argument("--smoke", action="store_true",
                  help="reduced same-family config (CPU-sized)")
  ap.add_argument("--steps", type=int, default=100)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=128)
  ap.add_argument("--lr", type=float, default=3e-4)
  ap.add_argument("--trim-frac", type=float, default=0.0)
  ap.add_argument("--router", default=None)
  ap.add_argument("--corrupt", type=float, default=0.0)
  ap.add_argument("--compress-grads", action="store_true")
  ap.add_argument("--ckpt-dir", default=None)
  ap.add_argument("--ckpt-every", type=int, default=50)
  ap.add_argument("--bench-json", default=None, metavar="PATH",
                  help="write a schema-v1 BENCH artifact (step-time "
                       "distribution + dispatch metrics) on exit")
  ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                  help="install an ExecutionPlan (repro.plan JSON) as the "
                       "active plan for every dispatch decision")
  ap.add_argument("--set", action="append", dest="overrides")
  args = ap.parse_args()

  if args.plan:
    repro_plan.set_active_plan(repro_plan.load_plan(args.plan))

  if args.smoke:
    from repro.configs.smoke import smoke_config
    cfg = smoke_config(args.arch)
  else:
    cfg = get_config(args.arch)
  over = parse_overrides(args.overrides)
  if args.trim_frac:
    over["loss_trim_fraction"] = args.trim_frac
  if args.router:
    over["router"] = args.router
  if over:
    cfg = dataclasses.replace(cfg, **over)

  opt_cfg = adamw.AdamWConfig(lr=args.lr)
  trainer = Trainer(cfg, opt_cfg, batch=args.batch, seq=args.seq,
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    compress_grads=args.compress_grads,
                    total_steps=args.steps, corrupt_fraction=args.corrupt)
  trainer.install_preemption_handler()
  state = trainer.init_or_restore()
  state, metrics = trainer.run(state, args.steps)
  print(f"[train] done at step {state.step}; "
        f"final loss {float(metrics.get('loss', float('nan'))):.4f}; "
        f"stragglers {trainer.straggler_events}")
  if args.bench_json:
    obs_artifacts.write_bench_artifact(
        args.bench_json, trainer.bench_results(metrics),
        obs_artifacts.collect_meta(
            suite="train", arch=args.arch, smoke=bool(args.smoke),
            batch=args.batch, seq=args.seq, steps=state.step,
            **repro_plan.plan_provenance()))


if __name__ == "__main__":
  main()

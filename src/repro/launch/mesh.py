"""Production mesh construction (assignment-pinned shapes).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
only ``dryrun.py`` forces the 512-device host platform).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
  # jax < 0.5 has no jax.sharding.AxisType; Auto is that build's only
  # behavior, so omitting the kwarg there is semantically identical.
  axis_type = getattr(jax.sharding, "AxisType", None)
  if axis_type is None:
    return jax.make_mesh(shape, axes)
  return jax.make_mesh(
      shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
  shape = (2, 16, 16) if multi_pod else (16, 16)
  axes = ("pod", "data", "model") if multi_pod else ("data", "model")
  return _make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
  """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
  return _make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple[str, ...]:
  return tuple(a for a in mesh.axis_names if a in ("pod", "data"))

"""Serving drivers: the soft-op engine and the LM prefill/decode loop.

Two modes share this entry point:

* ``--engine`` — the `repro.serving` micro-batching engine for the
  soft-sort/rank op family: a mixed-size synthetic request stream runs
  through plan-derived AOT warmup, shape-bucketed dynamic batching and
  admission control, and prints throughput/latency/occupancy (docs/
  SERVING.md).  ``--arch`` is not needed in this mode:

    PYTHONPATH=src python -m repro.launch.serve --engine \
        --engine-requests 500 --engine-max-batch 32

* LM mode (default, requires ``--arch``) — prefill the prompt batch
  once, then decode tokens autoregressively with a uniform position
  counter (continuous batching with per-row lengths is a documented
  extension — the cache layout already supports per-row fill levels):

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import plan as repro_plan
from repro.obs import artifacts as obs_artifacts
from repro.obs.tracing import trace_annotation


def greedy(logits):
  # Last axis is the vocabulary for every head layout, including audio
  # codebook heads (B, K, V) — argmax(-1) keeps the per-codebook structure.
  return jnp.argmax(logits, -1)


def run_engine(args) -> None:
  """Drive the repro.serving engine over a synthetic mixed-n stream."""
  from repro.obs import metrics
  from repro.obs.timing import percentiles
  from repro.serving import EngineConfig, ServingEngine, synthetic_stream

  ops = tuple(args.engine_ops.split(","))
  cfg = EngineConfig(
      ops=ops,
      min_bucket=args.engine_min_n,
      max_bucket=args.engine_max_n,
      max_batch=args.engine_max_batch,
      max_wait_ms=args.engine_max_wait_ms,
      queue_capacity=args.engine_queue,
      default_deadline_ms=args.engine_deadline_ms,
      impl=args.impl,
  )
  engine = ServingEngine(cfg, plan=repro_plan.get_active_plan())
  t0 = time.time()
  compiled = engine.warmup()
  t_warm = time.time() - t0
  print(f"[engine] warmed {compiled} executables over "
        f"{len(engine.policy.sizes)} n-buckets x "
        f"{len(engine.policy.row_sizes)} row-buckets in {t_warm:.1f}s")

  requests = synthetic_stream(
      args.engine_requests, seed=args.engine_seed, ops=ops,
      n_min=args.engine_min_n, n_max=args.engine_max_n,
      deadline_ms=args.engine_deadline_ms)
  t0 = time.time()
  with trace_annotation("repro_serve_engine"):
    results = engine.serve(requests)
  wall = time.time() - t0
  ok = [r for r in results if r.ok]
  shed = [r for r in results if not r.ok]
  lat = sorted(r.latency_us for r in ok) if ok else [0.0]
  p50, p95, p99 = percentiles(lat, (50, 95, 99))
  misses = sum(metrics.counters("aot_cache_miss").values())
  print(f"[engine] served {len(ok)}/{len(results)} requests "
        f"({len(shed)} shed) in {wall:.3f}s "
        f"({len(ok) / max(wall, 1e-9):.0f} req/s); "
        f"p50/p95/p99 latency {p50:.0f}/{p95:.0f}/{p99:.0f} us; "
        f"aot_cache_miss={misses}")

  if args.bench_json:
    results_rows = [{
        "name": "serve/engine_stream",
        "wall_us": wall * 1e6,
        "req_per_s": len(ok) / max(wall, 1e-9),
        "requests": len(results), "ok": len(ok), "shed": len(shed),
        "p50_us": p50, "p95_us": p95, "p99_us": p99,
        "aot_cache_miss_after_warmup": misses,
    }]
    obs_artifacts.write_bench_artifact(
        args.bench_json, results_rows,
        obs_artifacts.collect_meta(
            suite="serve-engine", ops=",".join(ops),
            requests=args.engine_requests,
            max_batch=cfg.max_batch, max_wait_ms=cfg.max_wait_ms,
            **repro_plan.plan_provenance()))


def run_lm(args) -> None:
  from repro.configs.base import get_config
  from repro.data.pipeline import pipeline_for_arch
  from repro.launch import steps as ST
  from repro.launch.dryrun import parse_overrides
  from repro.models import transformer as T

  if args.smoke:
    from repro.configs.smoke import smoke_config
    cfg = smoke_config(args.arch)
  else:
    cfg = get_config(args.arch)
  over = parse_overrides(args.overrides)
  if over:
    cfg = dataclasses.replace(cfg, **over)
  if cfg.frontend == "audio":
    raise SystemExit("audio decode takes frame embeddings; use the "
                     "examples/ drivers for musicgen")

  max_len = args.prompt_len + args.gen
  params = T.init_params(cfg, jax.random.PRNGKey(0))
  pipe = pipeline_for_arch(cfg, args.batch, args.prompt_len)
  batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()
           if k in ("tokens", "image_embeds")}

  prefill = jax.jit(ST.make_prefill_step(cfg, max_len))
  # Donate the KV caches (positional arg 1): each decode step writes the
  # caches in place instead of copying the full cache pytree per token.
  decode = jax.jit(ST.make_decode_step(cfg), donate_argnums=(1,))

  t0 = time.time()
  with trace_annotation("repro_serve_prefill"):
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
  t_prefill = time.time() - t0

  pos0 = args.prompt_len + (cfg.num_patches if cfg.frontend == "vision"
                            else 0)
  tok = greedy(logits)
  out_tokens = [np.asarray(tok)]
  t0 = time.time()
  with trace_annotation("repro_serve_decode"):
    for i in range(args.gen - 1):
      logits, caches = decode(params, caches, tok, jnp.int32(pos0 + i))
      tok = greedy(logits)
      out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
  t_decode = time.time() - t0

  gen = np.stack(out_tokens, axis=1)
  print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
        f"{t_prefill*1e3:.0f} ms; {args.gen - 1} decode steps in "
        f"{t_decode*1e3:.0f} ms "
        f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
  print("[serve] sample generations (first 2 rows):")
  for row in gen[:2]:
    print("  ", row.reshape(row.shape[0], -1)[:, 0].tolist())

  if args.bench_json:
    decode_steps = max(args.gen - 1, 1)
    results = [
        {"name": "serve/prefill", "wall_us": t_prefill * 1e6,
         "batch": args.batch, "prompt_len": args.prompt_len},
        {"name": "serve/decode_step",
         "wall_us": t_decode / decode_steps * 1e6,
         "batch": args.batch, "decode_steps": decode_steps,
         "tok_per_s": decode_steps * args.batch / max(t_decode, 1e-9)},
    ]
    obs_artifacts.write_bench_artifact(
        args.bench_json, results,
        obs_artifacts.collect_meta(
            suite="serve", arch=args.arch, smoke=bool(args.smoke),
            batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
            **repro_plan.plan_provenance()))


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default=None,
                  help="LM architecture (required unless --engine)")
  ap.add_argument("--smoke", action="store_true")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--prompt-len", type=int, default=32)
  ap.add_argument("--gen", type=int, default=16)
  ap.add_argument("--bench-json", default=None, metavar="PATH",
                  help="write a schema-v1 BENCH artifact (prefill/decode "
                       "walls + dispatch metrics) on exit")
  ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                  help="install an ExecutionPlan (repro.plan JSON) as the "
                       "active plan for every dispatch decision")
  ap.add_argument("--set", action="append", dest="overrides")
  # Soft-op serving engine mode (repro.serving).
  ap.add_argument("--engine", action="store_true",
                  help="serve the soft-op family through the repro.serving "
                       "micro-batching engine instead of the LM loop")
  ap.add_argument("--engine-ops",
                  default="soft_rank/l2/desc,soft_sort/l2/desc",
                  help="comma-separated repro.serving.SERVING_OPS keys")
  ap.add_argument("--engine-requests", type=int, default=500)
  ap.add_argument("--engine-seed", type=int, default=0)
  ap.add_argument("--engine-min-n", type=int, default=64)
  ap.add_argument("--engine-max-n", type=int, default=4096)
  ap.add_argument("--engine-max-batch", type=int, default=32)
  ap.add_argument("--engine-max-wait-ms", type=float, default=2.0)
  ap.add_argument("--engine-queue", type=int, default=1024)
  ap.add_argument("--engine-deadline-ms", type=float, default=None)
  ap.add_argument("--impl", default=None,
                  help="pin the isotonic backend for --engine mode")
  args = ap.parse_args()

  if args.plan:
    repro_plan.set_active_plan(repro_plan.load_plan(args.plan))

  if args.engine:
    run_engine(args)
    return
  if not args.arch:
    raise SystemExit("--arch is required unless --engine is given")
  run_lm(args)


if __name__ == "__main__":
  main()

"""Batched serving driver: prefill + greedy decode loop.

Serves a (reduced or full) architecture with batched requests: prefill the
prompt batch once, then decode tokens autoregressively with a uniform
position counter (continuous batching with per-row lengths is a documented
extension — the cache layout already supports per-row fill levels).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import plan as repro_plan
from repro.configs.base import get_config
from repro.data.pipeline import pipeline_for_arch
from repro.launch import steps as ST
from repro.launch.dryrun import parse_overrides
from repro.models import transformer as T
from repro.obs import artifacts as obs_artifacts
from repro.obs.tracing import trace_annotation


def greedy(logits):
  # Last axis is the vocabulary for every head layout, including audio
  # codebook heads (B, K, V) — argmax(-1) keeps the per-codebook structure.
  return jnp.argmax(logits, -1)


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", required=True)
  ap.add_argument("--smoke", action="store_true")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--prompt-len", type=int, default=32)
  ap.add_argument("--gen", type=int, default=16)
  ap.add_argument("--bench-json", default=None, metavar="PATH",
                  help="write a schema-v1 BENCH artifact (prefill/decode "
                       "walls + dispatch metrics) on exit")
  ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                  help="install an ExecutionPlan (repro.plan JSON) as the "
                       "active plan for every dispatch decision")
  ap.add_argument("--set", action="append", dest="overrides")
  args = ap.parse_args()

  if args.plan:
    repro_plan.set_active_plan(repro_plan.load_plan(args.plan))

  if args.smoke:
    from repro.configs.smoke import smoke_config
    cfg = smoke_config(args.arch)
  else:
    cfg = get_config(args.arch)
  over = parse_overrides(args.overrides)
  if over:
    cfg = dataclasses.replace(cfg, **over)
  if cfg.frontend == "audio":
    raise SystemExit("audio decode takes frame embeddings; use the "
                     "examples/ drivers for musicgen")

  max_len = args.prompt_len + args.gen
  params = T.init_params(cfg, jax.random.PRNGKey(0))
  pipe = pipeline_for_arch(cfg, args.batch, args.prompt_len)
  batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()
           if k in ("tokens", "image_embeds")}

  prefill = jax.jit(ST.make_prefill_step(cfg, max_len))
  decode = jax.jit(ST.make_decode_step(cfg))

  t0 = time.time()
  with trace_annotation("repro_serve_prefill"):
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
  t_prefill = time.time() - t0

  pos0 = args.prompt_len + (cfg.num_patches if cfg.frontend == "vision"
                            else 0)
  tok = greedy(logits)
  out_tokens = [np.asarray(tok)]
  t0 = time.time()
  with trace_annotation("repro_serve_decode"):
    for i in range(args.gen - 1):
      logits, caches = decode(params, caches, tok, jnp.int32(pos0 + i))
      tok = greedy(logits)
      out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
  t_decode = time.time() - t0

  gen = np.stack(out_tokens, axis=1)
  print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
        f"{t_prefill*1e3:.0f} ms; {args.gen - 1} decode steps in "
        f"{t_decode*1e3:.0f} ms "
        f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
  print("[serve] sample generations (first 2 rows):")
  for row in gen[:2]:
    print("  ", row.reshape(row.shape[0], -1)[:, 0].tolist())

  if args.bench_json:
    decode_steps = max(args.gen - 1, 1)
    results = [
        {"name": "serve/prefill", "wall_us": t_prefill * 1e6,
         "batch": args.batch, "prompt_len": args.prompt_len},
        {"name": "serve/decode_step",
         "wall_us": t_decode / decode_steps * 1e6,
         "batch": args.batch, "decode_steps": decode_steps,
         "tok_per_s": decode_steps * args.batch / max(t_decode, 1e-9)},
    ]
    obs_artifacts.write_bench_artifact(
        args.bench_json, results,
        obs_artifacts.collect_meta(
            suite="serve", arch=args.arch, smoke=bool(args.smoke),
            batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
            **repro_plan.plan_provenance()))


if __name__ == "__main__":
  main()

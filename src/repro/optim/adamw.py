"""AdamW from scratch (no optax in this environment), pytree-native.

Features used at scale:
  * moment dtype configurable (bf16 moments for grok-class models — the
    param+opt-state budget is what bounds chips, DESIGN.md §7);
  * global-norm clipping;
  * soft-quantile clipping (paper integration): the clip threshold is the
    differentiable soft q-quantile of the recent grad-norm history, so the
    threshold adapts to the run instead of being a fixed constant.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.operators import soft_quantile

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
  lr: float = 3e-4
  b1: float = 0.9
  b2: float = 0.95
  eps: float = 1e-8
  weight_decay: float = 0.1
  clip_norm: float = 1.0
  moment_dtype: str = "float32"
  # soft-quantile adaptive clipping (0 disables; else quantile in (0,1))
  quantile_clip: float = 0.0
  quantile_window: int = 64
  quantile_eps: float = 0.05


def init(cfg: AdamWConfig, params: Any) -> dict[str, Any]:
  mdt = jnp.dtype(cfg.moment_dtype)
  zeros = lambda p: jnp.zeros(p.shape, mdt)
  state = {
      "step": jnp.zeros((), jnp.int32),
      "m": jax.tree.map(zeros, params),
      "v": jax.tree.map(zeros, params),
  }
  if cfg.quantile_clip > 0:
    state["norm_history"] = jnp.full(
        (cfg.quantile_window,), cfg.clip_norm, jnp.float32)
  return state


def global_norm(tree: Any) -> Array:
  return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                      for x in jax.tree.leaves(tree)))


def update(
    cfg: AdamWConfig,
    grads: Any,
    state: dict[str, Any],
    params: Any,
    lr_scale: Array | float = 1.0,
):
  """Returns (new_params, new_state, metrics)."""
  step = state["step"] + 1
  gnorm = global_norm(grads)

  if cfg.quantile_clip > 0:
    hist = state["norm_history"]
    clip = soft_quantile(hist, cfg.quantile_clip, cfg.quantile_eps)
    clip = jnp.maximum(clip, 1e-6)
    hist = jnp.roll(hist, -1).at[-1].set(gnorm)
  else:
    clip = jnp.asarray(cfg.clip_norm, jnp.float32)
    hist = None
  scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))

  lr = cfg.lr * lr_scale
  b1, b2 = cfg.b1, cfg.b2
  bc1 = 1.0 - b1 ** step.astype(jnp.float32)
  bc2 = 1.0 - b2 ** step.astype(jnp.float32)
  mdt = jnp.dtype(cfg.moment_dtype)

  def upd(p, g, m, v, decay):
    g32 = g.astype(jnp.float32) * scale
    m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
    v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
    mhat = m32 / bc1
    vhat = v32 / bc2
    step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
    if decay:
      step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
    return new_p, m32.astype(mdt), v32.astype(mdt)

  # NOTE (§Perf, grok): chunking giant stacked leaves through lax.map was
  # tried to shrink the f32 update temporaries and REFUTED — map's stacked
  # outputs defeat input-output buffer donation, net +9 GiB.  The fused
  # whole-leaf update keeps donation intact.
  def upd_leaf(p, g, m, v):
    return upd(p, g, m, v, p.ndim >= 2)

  flat_p, treedef = jax.tree.flatten(params)
  flat_g = jax.tree.leaves(grads)
  flat_m = jax.tree.leaves(state["m"])
  flat_v = jax.tree.leaves(state["v"])
  out = [upd_leaf(p, g, m, v) for p, g, m, v in
         zip(flat_p, flat_g, flat_m, flat_v)]
  new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
  new_state = {
      "step": step,
      "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
      "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
  }
  if hist is not None:
    new_state["norm_history"] = hist
  metrics = {"grad_norm": gnorm, "clip_scale": scale, "clip_at": clip}
  return new_params, new_state, metrics

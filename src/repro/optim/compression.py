"""Gradient compression for slow (cross-pod) links: int8 + error feedback.

At 512+ chips the pod-to-pod reduction rides the slowest links; quantizing
the pod-axis all-reduce to int8 cuts those bytes 4x (bf16) at the cost of
quantization noise, which error feedback (residual accumulation) removes in
expectation.  Two entry points:

  * ``ef_int8_roundtrip``: quantize->dequantize with error-feedback state —
    the wire-format transform, applied to gradients in the trainer when
    ``--compress-grads`` is set (models the cross-pod wire exactly; the
    within-pod reduction stays full precision).
  * ``pod_psum_int8``: the real collective — a ``shard_map`` psum over the
    'pod' axis on int8-encoded values, used by the multi-pod train step.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def _quant_int8(x: Array) -> tuple[Array, Array]:
  amax = jnp.max(jnp.abs(x)) + 1e-12
  scale = amax / 127.0
  q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
  return q, scale


def _dequant(q: Array, scale: Array) -> Array:
  return q.astype(jnp.float32) * scale


def ef_int8_roundtrip(grads: Any, residual: Any):
  """Error-feedback int8 round trip over a gradient pytree.

  Returns (decoded grads, new residual).  residual has grad dtypes/shapes.
  """

  def one(g, r):
    g32 = g.astype(jnp.float32) + r.astype(jnp.float32)
    q, scale = _quant_int8(g32)
    dec = _dequant(q, scale)
    return dec.astype(g.dtype), (g32 - dec).astype(g.dtype)

  flat_g, td = jax.tree.flatten(grads)
  flat_r = jax.tree.leaves(residual)
  out = [one(g, r) for g, r in zip(flat_g, flat_r)]
  return (jax.tree.unflatten(td, [o[0] for o in out]),
          jax.tree.unflatten(td, [o[1] for o in out]))


def init_residual(grads_shape: Any) -> Any:
  return jax.tree.map(lambda g: jnp.zeros(g.shape, g.dtype), grads_shape)


def pod_psum_int8(x: Array, mesh, spec: P) -> Array:
  """All-reduce over the 'pod' axis with int8 wire format (shard_map)."""
  from jax.experimental.shard_map import shard_map

  def body(local):
    q, scale = _quant_int8(local)
    # Sum dequantized shards; scales are per-pod so psum the decoded value.
    dec = _dequant(q, scale)
    return jax.lax.psum(dec, "pod").astype(local.dtype)

  return shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                   check_rep=False)(x)

"""xlstm-350m: mLSTM + sLSTM blocks (7:1), O(1) recurrent state.

[arXiv:2405.04517; unverified]  d_ff=0 per assignment: blocks carry their
own projection factors (mLSTM pf=2, sLSTM pf=4/3), noted in DESIGN.md.
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_cycle=("mlstm",) * 7 + ("slstm",),
    norm="layernorm",
    supports_long_context=True,
    remat="full",
    grad_accum=8,
))

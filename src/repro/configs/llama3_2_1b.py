"""llama3.2-1b: 16L dense GQA (kv=8), 128k vocab, tied embeddings.

[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    block_cycle=("dense",),
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    remat="full",
    grad_accum=4,
))

"""deepseek-v2-lite-16b: 27L MLA + MoE (64 routed top-6, 2 shared).

[arXiv:2405.04434; hf]  MLA: kv_lora_rank=512, rope_dim=64, nope=128, v=128.
The paper-technique router (soft top-k via permutahedron projection) is the
DEFAULT here; `--router softmax_topk` restores the standard baseline.
Deviation noted in DESIGN.md: V2-Lite's single leading dense layer is made
MoE for a uniform scan (27x identical blocks).
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=192,           # qk_nope + qk_rope
    d_ff=1408,
    vocab_size=102400,
    block_cycle=("mla_moe",),
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    router="soft_topk",
    router_eps=1.0,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    fsdp=True,
    seq_shard_activations=True,
    remat="full",
    grad_accum=8,
))

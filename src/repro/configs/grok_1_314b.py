"""grok-1-314b: 64L MoE (8 experts top-2), GQA kv=8, 131k vocab.

[hf:xai-org/grok-1; unverified]  Soft-top-k router by default (paper
technique); FSDP + sequence-sharded activations (the params do not fit
otherwise: 314B * 14B/param would need ~18GB/chip un-sharded opt state).
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    block_cycle=("moe",),
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32768,
    router="soft_topk",
    router_eps=1.0,
    logit_softcap=30.0,
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    fsdp=True,
    seq_shard_activations=True,
    remat="full",
    grad_accum=8,
    grad_accum_dtype="bfloat16",
    xent_chunk=512,
))

"""Reduced same-family smoke variants of every assigned architecture.

Same block cycles, layer kinds, router, and attention flavors as the full
configs — just small widths/depths/vocabs so one forward/train step runs on
CPU in seconds.  Used by tests/test_models_smoke.py.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, all_assigned, get_config


def smoke_config(name: str) -> ArchConfig:
  cfg = get_config(name)
  cycle_len = len(cfg.block_cycle)
  reductions = dict(
      name=f"{cfg.name}-smoke",
      num_layers=2 * cycle_len if cycle_len > 1 else 2,
      d_model=64,
      num_heads=4,
      num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads
      else 4,
      head_dim=16,
      d_ff=128 if cfg.d_ff else 0,
      vocab_size=256,
      window_size=min(cfg.window_size, 32) if cfg.window_size else 0,
      xent_chunk=16,
      q_chunk=16,
      kv_chunk=16,
      moe_group_size=32,
      grad_accum=1,
      dtype="float32",
      remat="none",
      fsdp=False,
      seq_shard_activations=False,
  )
  if cfg.num_experts:
    reductions.update(
        num_experts=8,
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=32,
    )
  if cfg.kv_lora_rank:
    reductions.update(
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        head_dim=24)
  if cfg.lru_width:
    reductions.update(lru_width=64)
  if cfg.num_patches:
    reductions.update(num_patches=8)
  return dataclasses.replace(cfg, **reductions)


def all_smoke_configs() -> list[ArchConfig]:
  return [smoke_config(n) for n in all_assigned()]

"""llava-next-mistral-7b: mistral-7B backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
``input_specs`` supplies precomputed patch embeddings (stub frontend per
assignment); loss covers the text region only.
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_cycle=("dense",),
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
    num_patches=576,
    fsdp=True,
    remat="full",
    grad_accum=8,
))

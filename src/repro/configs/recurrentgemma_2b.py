"""recurrentgemma-2b: RG-LRU + local-attention hybrid (Griffin), 1 attn : 2 rec.

[arXiv:2402.19427; hf]  O(1) recurrent state + 2k-window MQA -> runs the
long_500k cell.
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_cycle=("rg", "rg", "local"),
    window_size=2048,
    lru_width=2560,
    conv_width=4,
    mlp_variant="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    supports_long_context=True,
    fsdp=True,
    remat="full",
    grad_accum=8,
))

"""musicgen-large: 48L decoder over EnCodec tokens, 4 codebooks.

[arXiv:2306.05284; hf]  Audio frontend is a stub: ``input_specs`` provides
precomputed frame embeddings; 4 parallel codebook heads (vocab 2048 each).
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    block_cycle=("dense",),
    mlp_variant="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    frontend="audio",
    num_codebooks=4,
    fsdp=True,
    remat="full",
    grad_accum=8,
))

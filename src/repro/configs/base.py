"""Architecture configuration schema + registry.

One ``ArchConfig`` instance per assigned architecture (exact numbers from the
assignment table) plus reduced "smoke" variants of the same family for CPU
tests.  ``layer_kinds()`` expands the block-pattern cycle into a per-layer
kind list; ``plan_segments()`` groups it into scannable segments.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

_REGISTRY: dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
  name: str
  family: str                     # dense|moe|vlm|hybrid|ssm|audio
  num_layers: int
  d_model: int
  num_heads: int
  num_kv_heads: int
  head_dim: int
  d_ff: int
  vocab_size: int

  # Block pattern: cycle of layer kinds, applied as kind[i % len(cycle)].
  # Kinds: dense | local | moe | local_moe | mla_dense | mla_moe | rg |
  #        mlstm | slstm
  block_cycle: tuple[str, ...] = ("dense",)
  window_size: int = 0            # sliding window for "local" layers

  # MoE
  num_experts: int = 0
  experts_per_token: int = 0
  num_shared_experts: int = 0
  moe_d_ff: int = 0
  router: str = "softmax_topk"    # softmax_topk | soft_topk (paper)
  router_eps: float = 1.0
  capacity_factor: float = 1.25
  moe_group_size: int = 512       # routing-group tokens (bounds dispatch cost)

  # MLA (deepseek)
  kv_lora_rank: int = 0
  qk_nope_dim: int = 0
  qk_rope_dim: int = 0
  v_head_dim: int = 0

  # Recurrent (RG-LRU)
  lru_width: int = 0
  conv_width: int = 4

  # MLP / norm / embeddings
  mlp_variant: str = "swiglu"     # swiglu | geglu | gelu
  norm: str = "rmsnorm"           # rmsnorm | layernorm
  rope_theta: float = 10000.0
  tie_embeddings: bool = False
  logit_softcap: float = 0.0

  # Modality frontend stub
  frontend: str = "none"          # none | vision | audio
  num_codebooks: int = 0          # audio: parallel output heads
  num_patches: int = 0            # vision: patch-embedding prefix length

  # Numerics / training-step shape
  dtype: str = "bfloat16"
  remat: str = "full"             # none | dots | full
  grad_accum: int = 1
  grad_accum_dtype: str = "float32"  # bf16 for param-bound giants (grok)
  xent_chunk: int = 1024          # sequence chunking for the LM-head loss
  q_chunk: int = 512              # flash-attention query block
  kv_chunk: int = 1024            # flash-attention kv block

  # Paper-technique knobs
  loss_trim_fraction: float = 0.0   # soft-LTS token trimming (0 = off)
  loss_trim_eps: float = 1e-2

  # Sharding strategy
  fsdp: bool = False              # also shard weights/opt-state over data
  seq_shard_activations: bool = False
  supports_long_context: bool = False  # run long_500k? (sub-quadratic)

  @property
  def attn_dim(self) -> int:
    return self.num_heads * self.head_dim

  def layer_kinds(self) -> list[str]:
    cyc = self.block_cycle
    return [cyc[i % len(cyc)] for i in range(self.num_layers)]

  def plan_segments(self) -> list[tuple[tuple[str, ...], int]]:
    """Group layers into (cycle, repeats) segments for lax.scan stacking.

    The full cycle is scanned ``num_layers // len(cycle)`` times; any
    remainder layers form a trailing unrolled segment (repeats=1 each
    sub-cycle so params still stack uniformly).
    """
    kinds = self.layer_kinds()
    cyc = tuple(self.block_cycle)
    reps = len(kinds) // len(cyc)
    segments: list[tuple[tuple[str, ...], int]] = []
    if reps > 0:
      segments.append((cyc, reps))
    rem = kinds[reps * len(cyc):]
    if rem:
      segments.append((tuple(rem), 1))
    return segments


def register(cfg: ArchConfig) -> ArchConfig:
  assert cfg.name not in _REGISTRY, cfg.name
  _REGISTRY[cfg.name] = cfg
  return cfg


def get_config(name: str) -> ArchConfig:
  if name not in _REGISTRY:
    # Import the module of the same name to trigger registration.
    import importlib
    mod = name.replace("-", "_").replace(".", "_")
    importlib.import_module(f"repro.configs.{mod}")
  return _REGISTRY[name]


def registered() -> list[str]:
  return sorted(_REGISTRY)


def all_assigned() -> list[str]:
  """The 10 assigned architectures (import side-effect registers them)."""
  names = [
      "gemma3-12b", "stablelm-3b", "llama3.2-1b", "tinyllama-1.1b",
      "deepseek-v2-lite-16b", "grok-1-314b", "llava-next-mistral-7b",
      "recurrentgemma-2b", "xlstm-350m", "musicgen-large",
  ]
  for n in names:
    get_config(n)
  return names

"""stablelm-3b: 32L dense MHA (kv=32), LayerNorm+GELU family.

[hf:stabilityai/stablelm-2-1_6b scaled per assignment; unverified]
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    block_cycle=("dense",),
    mlp_variant="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    fsdp=True,
    remat="full",
    grad_accum=8,
))

"""tinyllama-1.1b: 22L llama2-family GQA (kv=4).  [arXiv:2401.02385; hf]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    block_cycle=("dense",),
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    remat="full",
    grad_accum=4,
))

"""gemma3-12b: 48L dense, 5:1 local:global sliding-window, 262k vocab.

[hf:google/gemma-3-1b-pt scaled per assignment; unverified]
Treated as hybrid for long_500k: local layers are O(S*W); the 1-in-6 global
layers use sequence-sharded KV (see DESIGN.md §6).
"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    block_cycle=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    mlp_variant="geglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    fsdp=True,
    seq_shard_activations=True,
    supports_long_context=True,
    remat="full",
    grad_accum=8,
    xent_chunk=512,
))

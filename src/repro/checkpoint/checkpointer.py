"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-independent.

Format: one directory per step containing
  manifest.json   — tree structure, shapes, dtypes, user metadata
  arrays.npz      — flattened leaves keyed by tree path

Writes go to ``<dir>/tmp.<step>`` and are ``os.replace``d into place, so a
preemption mid-write never corrupts the latest checkpoint.  Arrays are
stored *unsharded* (gathered) with path keys, so restore can re-shard onto
any mesh shape — this is the elastic-restart path: a 512-chip checkpoint
restores onto 256 or 1024 chips unchanged (``restore(..., shardings=)``).

``AsyncCheckpointer`` runs saves on a background thread (double-buffered:
at most one pending save; the trainer never blocks on I/O unless two saves
collide).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
  flat = {}
  for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
    key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)
    arr = np.asarray(leaf)
    if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
      # npz cannot store ml_dtypes natively: raw-encode, record the dtype
      # in the manifest, and view back on restore.
      arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
    flat[key] = arr
  return flat


def _treedef_of(tree: Any):
  return jax.tree_util.tree_structure(tree)


def save(directory: str, step: int, tree: Any,
         metadata: dict | None = None, keep: int = 3) -> str:
  os.makedirs(directory, exist_ok=True)
  tmp = os.path.join(directory, f"tmp.{step}")
  final = os.path.join(directory, f"step_{step:010d}")
  if os.path.exists(tmp):
    shutil.rmtree(tmp)
  os.makedirs(tmp)

  flat = _flatten(tree)
  np.savez(os.path.join(tmp, "arrays.npz"), **flat)
  manifest = {
      "step": step,
      "keys": sorted(flat),
      "shapes": {k: list(v.shape) for k, v in flat.items()},
      "dtypes": {k: str(v.dtype) for k, v in flat.items()},
      "metadata": metadata or {},
  }
  with open(os.path.join(tmp, "manifest.json"), "w") as f:
    json.dump(manifest, f)
  if os.path.exists(final):
    shutil.rmtree(final)
  os.replace(tmp, final)
  _gc(directory, keep)
  return final


def _gc(directory: str, keep: int) -> None:
  steps = sorted(all_steps(directory))
  for s in steps[:-keep] if keep else []:
    shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                  ignore_errors=True)


def all_steps(directory: str) -> list[int]:
  if not os.path.isdir(directory):
    return []
  out = []
  for name in os.listdir(directory):
    if name.startswith("step_"):
      out.append(int(name.split("_")[1]))
  return sorted(out)


def latest_step(directory: str) -> int | None:
  steps = all_steps(directory)
  return steps[-1] if steps else None


def restore(directory: str, like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
  """Restore into the structure of `like`.

  ``shardings``: optional matching pytree of NamedSharding — arrays are
  placed shard-by-shard onto the (possibly different) live mesh, which is
  the elastic-scaling path.
  """
  if step is None:
    step = latest_step(directory)
    if step is None:
      raise FileNotFoundError(f"no checkpoints under {directory}")
  path = os.path.join(directory, f"step_{step:010d}")
  with open(os.path.join(path, "manifest.json")) as f:
    manifest = json.load(f)
  data = np.load(os.path.join(path, "arrays.npz"))

  flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
  leaves = []
  flat_shard = (jax.tree_util.tree_leaves(shardings)
                if shardings is not None else [None] * len(flat_like))
  for (p, proto), sh in zip(flat_like, flat_shard):
    key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in p)
    arr = data[key]
    want = np.dtype(proto.dtype)
    if arr.dtype != want and arr.dtype in (np.uint16, np.uint8) and (
        want.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2")):
      arr = arr.view(want)  # undo the raw encoding from _flatten
    if sh is not None:
      leaves.append(jax.device_put(arr, sh))
    else:
      leaves.append(jax.numpy.asarray(arr, dtype=proto.dtype))
  tree = jax.tree_util.tree_unflatten(treedef, leaves)
  return tree, manifest["metadata"]


class AsyncCheckpointer:
  """Background-thread saver: trainer thread never blocks on disk."""

  def __init__(self, directory: str, keep: int = 3):
    self.directory = directory
    self.keep = keep
    self._pending: threading.Thread | None = None
    self._error: BaseException | None = None

  def save(self, step: int, tree: Any, metadata: dict | None = None):
    self.wait()  # at most one in flight
    host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation

    def work():
      try:
        save(self.directory, step, host_tree, metadata, self.keep)
      except BaseException as e:  # surfaced on next wait()
        self._error = e

    self._pending = threading.Thread(target=work, daemon=True)
    self._pending.start()

  def wait(self):
    if self._pending is not None:
      self._pending.join()
      self._pending = None
    if self._error is not None:
      err, self._error = self._error, None
      raise err

"""Fused vs composed projection pipeline -> BENCH_projection.json.

The tentpole evidence for the fused projection op (ISSUE 8): end-to-end
``soft_rank`` forward and forward+backward, per regularization, for both
registered projection paths — ``"fused"`` (whole-pipeline custom VJP,
packed integer sorts, gather-only backward) and ``"composed"`` (the
reference chain of four differentiable primitives) — measured *in the same
run* so the speedup column is an apples-to-apples ratio.  Each cell also
records the bare isotonic solve (``iso_fwd_us``) and the derived
``solver_share`` so the wrapper-vs-solver split is tracked per PR.

The acceptance bar lives in the ``projection/<reg>/speedup/...`` rows:
fused must be >= 2x composed on e2e fwd+bwd for l2/scan at n=1024, b=8 on
CPU (``tools/check_backends.py --bench-projection`` gates >= 1x in CI so a
regression can never land silently).
"""

from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import soft_rank
from repro.core.isotonic import isotonic_kl, isotonic_l2
from repro import plan as plan_mod
from repro.kernels import dispatch as dispatch_mod
from repro.obs import artifacts as obs_artifacts

BATCH = 8
PROJ_NS = (1024, 4096)
SMOKE_NS = (1024,)        # the acceptance cell must survive the smoke cut
IMPL = "scan"             # the off-TPU auto default; fixes the solver so
                          # the two paths differ only in the wrapper


@contextlib.contextmanager
def _projection_path(path: str):
  """Select the projection path for everything traced inside the block."""
  prev = os.environ.get(dispatch_mod.PROJECTION_ENV_VAR)
  os.environ[dispatch_mod.PROJECTION_ENV_VAR] = path
  try:
    yield
  finally:
    if prev is None:
      os.environ.pop(dispatch_mod.PROJECTION_ENV_VAR, None)
    else:
      os.environ[dispatch_mod.PROJECTION_ENV_VAR] = prev


def run(smoke: bool = False,
        out_path: str = "BENCH_projection.json") -> dict:
  """Time both projection paths and write the schema-v1 artifact."""
  import repro.core.projection  # noqa: F401  (populate the registry)
  ns = SMOKE_NS if smoke else PROJ_NS
  rng = np.random.default_rng(0)
  iters = 3 if smoke else 5

  results = []
  for n in ns:
    theta = jnp.array(rng.normal(size=(BATCH, n)).astype(np.float32))
    for reg in ("l2", "kl"):
      # Bare solver timing: identical for both paths by construction
      # (same backend, same flattened batch) — measured once per cell.
      if reg == "l2":
        iso = jax.jit(functools.partial(isotonic_l2, impl=IMPL))
        iso_args = (theta,)
      else:
        iso = jax.jit(functools.partial(isotonic_kl, impl=IMPL))
        iso_args = (theta, jnp.zeros_like(theta))
      iso_fwd_us = time_fn(iso, *iso_args, warmup=1, iters=iters)

      cell: dict[str, dict] = {}
      for path in sorted(set(
          dispatch_mod.registered_backends("projection", reg))):
        name = f"projection/{reg}/{path}/n={n}/b={BATCH}"
        with _projection_path(path):
          fwd = jax.jit(functools.partial(
              soft_rank, regularization_strength=0.1, regularization=reg,
              impl=IMPL))
          bwd = jax.jit(jax.grad(lambda t, f=fwd: jnp.sum(f(t) ** 2)))
          e2e_fwd = time_fn(fwd, theta, warmup=2, iters=iters, name=name)
          e2e_fwd_bwd = time_fn(bwd, theta, warmup=2, iters=iters,
                                name=name + "/bwd")
        rec = {
            "name": name, "op": "soft_rank", "regularization": reg,
            "backend": path, "n": n, "batch": BATCH, "impl": IMPL,
            "e2e_fwd_us": e2e_fwd, "e2e_fwd_bwd_us": e2e_fwd_bwd,
            "iso_fwd_us": iso_fwd_us,
            "solver_share": round(iso_fwd_us / e2e_fwd, 4),
        }
        results.append(rec)
        cell[path] = rec
        emit(name, e2e_fwd,
             f"fwd; fwd+bwd={e2e_fwd_bwd:.1f}us; "
             f"solver_share={rec['solver_share']:.2f}", collect=False)

      fused, composed = cell.get("fused"), cell.get("composed")
      if fused and composed:
        speedup = composed["e2e_fwd_bwd_us"] / fused["e2e_fwd_bwd_us"]
        results.append({
            "name": f"projection/{reg}/speedup/n={n}/b={BATCH}",
            "op": "soft_rank", "regularization": reg,
            "backend": "fused_vs_composed", "n": n, "batch": BATCH,
            "impl": IMPL,
            "fused_fwd_bwd_us": fused["e2e_fwd_bwd_us"],
            "composed_fwd_bwd_us": composed["e2e_fwd_bwd_us"],
            "fwd_speedup_x": round(
                composed["e2e_fwd_us"] / fused["e2e_fwd_us"], 3),
            "speedup_x": round(speedup, 3),
        })
        emit(f"projection/{reg}/speedup/n={n}/b={BATCH}",
             fused["e2e_fwd_bwd_us"],
             f"fused is {speedup:.2f}x vs composed (fwd+bwd)",
             collect=False)

  meta = obs_artifacts.collect_meta(
      smoke=smoke, suite="projection", batch=BATCH, impl=IMPL,
      default_path=dispatch_mod.resolve_projection(None),
      **plan_mod.plan_provenance())
  return obs_artifacts.write_bench_artifact(out_path, results, meta)


if __name__ == "__main__":
  run()

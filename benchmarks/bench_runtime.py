"""Paper Figure 4 (right): runtime vs input dimension n; backend sweep.

Part 1 (``run``) compares our O(n log n) soft rank (Q and E) against the
paper's baselines: OT/Sinkhorn (O(T n^2)) and All-pairs (O(n^2)),
forward-only and with backpropagation, on a batch of vectors (batch scaled
for single-core CPU; the paper used batch 128 on GPU).  The claim being
reproduced: our operators' runtime is nearly flat in n while baselines grow
quadratically and exhaust memory first.

Part 2 (``run_backend_sweep``) sweeps the dispatch-layer backends
("lax" | "scan" | "pallas" | "minimax") over n x batch and writes the
``BENCH_runtime.json`` artifact that CI archives.  Combinations that are
infeasible for a backend on the current platform (minimax's O(batch * n^2)
memory, the Pallas interpreter off-TPU) are recorded as skipped rather than
silently dropped.

Part 3 (``run_depth_curve``) isolates the paper's complexity claim on
hardware: the sequential O(n)-depth stack machine ("lax") against the
O(log n)-depth divide-and-conquer machine ("scan") on the bare isotonic
solve across a geometric n sweep -> ``BENCH_depth_curve.json``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import soft_rank
from repro.core.baselines import allpairs_rank, ot_rank
from repro.core.isotonic import isotonic_kl, isotonic_l2
from repro import plan as plan_mod
from repro.kernels import dispatch as dispatch_mod
from repro.obs import artifacts as obs_artifacts

BATCH = 8
NS = (100, 500, 1000, 2000)      # paper used up to 5000 on GPU; CPU-scaled
OT_ITERS = 50
BWD_MAX_N = 1000                 # O(n^2) baselines w/ backprop OOM/time out
                                 # first — exactly the paper's point


def run():
  rng = np.random.default_rng(0)

  for n in NS:
    theta = jnp.array(rng.normal(size=(BATCH, n)).astype(np.float32))

    fns = {
        "soft_rank_q": jax.jit(lambda t: soft_rank(t, 1e-1, "l2")),
        "soft_rank_e": jax.jit(lambda t: soft_rank(t, 1e-1, "kl")),
        "allpairs": jax.jit(lambda t: allpairs_rank(t, 0.1)),
        f"ot_sinkhorn_t{OT_ITERS}": jax.jit(
            lambda t: ot_rank(t, 1e-2, num_iters=OT_ITERS)),
    }
    for name, fn in fns.items():
      us = time_fn(fn, theta, iters=3)
      emit(f"fig4_runtime/{name}/n={n}", us, f"batch={BATCH},fwd")

    grads = {
        "soft_rank_q": jax.jit(
            jax.grad(lambda t: jnp.sum(soft_rank(t, 1e-1, "l2") ** 2))),
        "allpairs": jax.jit(
            jax.grad(lambda t: jnp.sum(allpairs_rank(t, 0.1) ** 2))),
        f"ot_sinkhorn_t{OT_ITERS}": jax.jit(
            jax.grad(lambda t: jnp.sum(ot_rank(t, 1e-2, OT_ITERS) ** 2))),
    }
    for name, fn in grads.items():
      if n > BWD_MAX_N and name != "soft_rank_q":
        emit(f"fig4_runtime_bwd/{name}/n={n}", float("nan"),
             "skipped: O(n^2) baseline beyond CPU budget")
        continue
      us = time_fn(fn, theta, iters=3)
      emit(f"fig4_runtime_bwd/{name}/n={n}", us, f"batch={BATCH},fwd+bwd")


# ---------------------------------------------------------------------------
# Backend sweep -> BENCH_runtime.json
# ---------------------------------------------------------------------------

# 1024 is in both tiers on purpose: the scan-vs-lax >=2x acceptance bar is
# stated at n >= 1024, so even smoke artifacts carry the evidence cell.
# 4096 sits between the acceptance cell and the tail so the e2e/solver
# split is visible where the sort fast path matters most.
SWEEP_NS = (100, 1024, 4096, 10000)
SWEEP_BATCHES = (1, 32, 256)
SMOKE_NS = (64, 1024)
SMOKE_BATCHES = (1, 8)

# Feasibility caps keep the sweep bounded off-TPU; every skip is recorded.
_MINIMAX_MAX_ELEMS = 64e6       # batch * n^2 f32 intermediates (~256 MB)
_INTERPRET_MAX_CELLS = 4096     # Pallas interpreter runs Python per step
_INTERPRET_MAX_N = 1000


def _feasibility(backend: str, n: int, batch: int, platform: str) -> str:
  """Empty string if runnable, else the reason to skip."""
  if backend == "minimax" and batch * n * n > _MINIMAX_MAX_ELEMS:
    return f"minimax needs batch*n^2 = {batch * n * n:.0f} f32 elems"
  if backend == "pallas" and platform != "tpu":
    if n > _INTERPRET_MAX_N or n * batch > _INTERPRET_MAX_CELLS:
      return "pallas interpret mode too slow off-TPU at this size"
  return ""


def run_backend_sweep(smoke: bool = False,
                      out_path: str = "BENCH_runtime.json") -> dict:
  """Time soft_rank fwd and fwd+bwd per backend over n x batch; write the
  schema-v1 ``BENCH_runtime.json`` artifact (repro.obs.artifacts), whose
  ``metrics`` block carries the per-backend dispatch-resolution counters
  accumulated during the sweep."""
  platform = jax.default_backend()
  ns = SMOKE_NS if smoke else SWEEP_NS
  batches = SMOKE_BATCHES if smoke else SWEEP_BATCHES
  backends = dispatch_mod.registered_backends("isotonic", "l2")
  rng = np.random.default_rng(0)
  iters = 2 if smoke else 3

  results = []
  for n in ns:
    for batch in batches:
      theta = jnp.array(rng.normal(size=(batch, n)).astype(np.float32))
      for backend in sorted(set(backends)):
        for reg in ("l2", "kl"):
          name = f"backend_sweep/{reg}/{backend}/n={n}/b={batch}"
          rec = {"name": name, "op": "soft_rank", "regularization": reg,
                 "backend": backend, "n": n, "batch": batch}
          skip = _feasibility(backend, n, batch, platform)
          if skip:
            rec["skipped"] = skip
            results.append(rec)
            emit(name, float("nan"), f"skipped: {skip}", collect=False)
            continue
          fwd = jax.jit(functools.partial(
              soft_rank, regularization_strength=0.1, regularization=reg,
              impl=backend))
          rec["fwd_us"] = time_fn(fwd, theta, warmup=1, iters=iters,
                                  name=name)
          bwd = jax.jit(jax.grad(lambda t, f=fwd: jnp.sum(f(t) ** 2)))
          rec["fwd_bwd_us"] = time_fn(bwd, theta, warmup=1, iters=iters,
                                      name=name + "/bwd")
          # Bare solver column: soft_rank shares an O(n log n) sort +
          # unpermute across all backends, which dilutes the backend
          # difference at large batch — iso_fwd_us isolates what the
          # backends actually differ on.
          if reg == "l2":
            iso = jax.jit(functools.partial(isotonic_l2, impl=backend))
            iso_args = (theta,)
          else:
            iso = jax.jit(functools.partial(isotonic_kl, impl=backend))
            iso_args = (theta, jnp.zeros_like(theta))
          rec["iso_fwd_us"] = time_fn(iso, *iso_args, warmup=1, iters=iters,
                                      name=name + "/iso")
          # e2e_fwd_us aliases fwd_us under the projection-suite column
          # name, and solver_share = iso/e2e makes the wrapper-vs-solver
          # split a first-class per-cell stat (a share near 1.0 means the
          # backend is the bottleneck; near 0 means sort/permutation
          # overhead dominates and the fused projection path is what to
          # optimize).
          rec["e2e_fwd_us"] = rec["fwd_us"]
          rec["solver_share"] = round(rec["iso_fwd_us"] / rec["fwd_us"], 4)
          results.append(rec)
          emit(name, rec["fwd_us"],
               f"fwd; bwd={rec['fwd_bwd_us']:.1f}us; "
               f"iso={rec['iso_fwd_us']:.1f}us; "
               f"solver_share={rec['solver_share']:.2f}",
               collect=False)

  meta = obs_artifacts.collect_meta(
      smoke=smoke,
      suite="backend_sweep",
      default_backend=dispatch_mod.get_default_backend(),
      auto_resolves_to=dispatch_mod.resolve_backend(
          "isotonic", "l2", None, shape=(max(batches), max(ns)),
          platform=platform),
      **plan_mod.plan_provenance(),
  )
  return obs_artifacts.write_bench_artifact(out_path, results, meta)


# ---------------------------------------------------------------------------
# Depth-vs-n curve -> BENCH_depth_curve.json
# ---------------------------------------------------------------------------

DEPTH_NS = (64, 256, 1024, 4096, 16384)
DEPTH_SMOKE_NS = (64, 1024)
DEPTH_BATCH = 8
_DEPTH_LAX_MAX_N = 16384         # O(n)-depth machine: past this the curve's
                                 # shape is already unambiguous on CPU


def run_depth_curve(smoke: bool = False,
                    out_path: str = "BENCH_depth_curve.json") -> dict:
  """Time the bare isotonic solve (fwd and fwd+bwd) for the O(n)-depth
  "lax" machine vs the O(log n)-depth "scan" machine across a geometric n
  sweep, and record the scan/lax speedup per cell.  This is the hardware
  realization of the paper's O(n log n) claim: same exact solution, the
  sequential-depth difference is the whole effect."""
  platform = jax.default_backend()
  ns = DEPTH_SMOKE_NS if smoke else DEPTH_NS
  rng = np.random.default_rng(0)
  iters = 2 if smoke else 3

  results = []
  for n in ns:
    theta = jnp.array(rng.normal(size=(DEPTH_BATCH, n)).astype(np.float32))
    w = jnp.zeros((DEPTH_BATCH, n), np.float32)
    cell: dict[tuple[str, str], dict] = {}
    for backend in ("lax", "scan"):
      for reg in ("l2", "kl"):
        name = f"depth_curve/{reg}/{backend}/n={n}"
        rec = {"name": name, "op": "isotonic", "regularization": reg,
               "backend": backend, "n": n, "batch": DEPTH_BATCH}
        if backend == "lax" and n > _DEPTH_LAX_MAX_N:
          rec["skipped"] = (
              f"lax O(n)-depth machine beyond CPU budget at n={n}")
          results.append(rec)
          emit(name, float("nan"), f"skipped: {rec['skipped']}",
               collect=False)
          continue
        if reg == "l2":
          fwd = jax.jit(functools.partial(isotonic_l2, impl=backend))
          args = (theta,)
        else:
          fwd = jax.jit(functools.partial(isotonic_kl, impl=backend))
          args = (theta, w)
        rec["fwd_us"] = time_fn(fwd, *args, warmup=1, iters=iters,
                                name=name)
        bwd = jax.jit(jax.grad(lambda *a, f=fwd: jnp.sum(f(*a) ** 2)))
        rec["fwd_bwd_us"] = time_fn(bwd, *args, warmup=1, iters=iters,
                                    name=name + "/bwd")
        results.append(rec)
        cell[(reg, backend)] = rec
        emit(name, rec["fwd_us"], f"fwd; bwd={rec['fwd_bwd_us']:.1f}us",
             collect=False)
    for reg in ("l2", "kl"):
      lax_rec = cell.get((reg, "lax"))
      scan_rec = cell.get((reg, "scan"))
      if lax_rec and scan_rec:
        speedup = lax_rec["fwd_us"] / scan_rec["fwd_us"]
        results.append({
            "name": f"depth_curve/{reg}/speedup/n={n}",
            "op": "isotonic", "regularization": reg,
            "backend": "scan_vs_lax", "n": n, "batch": DEPTH_BATCH,
            "lax_fwd_us": lax_rec["fwd_us"],
            "scan_fwd_us": scan_rec["fwd_us"],
            "speedup_x": round(speedup, 3),
        })
        emit(f"depth_curve/{reg}/speedup/n={n}", lax_rec["fwd_us"],
             f"scan is {speedup:.2f}x vs lax", collect=False)

  meta = obs_artifacts.collect_meta(
      smoke=smoke, suite="depth_curve", platform_note=platform,
      batch=DEPTH_BATCH, **plan_mod.plan_provenance())
  return obs_artifacts.write_bench_artifact(out_path, results, meta)


if __name__ == "__main__":
  run()
  run_backend_sweep()
  run_depth_curve()

"""Paper Figure 4 (right): runtime vs input dimension n.

Compares our O(n log n) soft rank (Q and E) against the paper's baselines:
OT/Sinkhorn (O(T n^2)) and All-pairs (O(n^2)), forward-only and with
backpropagation, on a batch of vectors (batch scaled for single-core CPU;
the paper used batch 128 on GPU).  The claim being reproduced: our
operators' runtime is nearly flat in n while baselines grow quadratically
and exhaust memory first.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import soft_rank
from repro.core.baselines import allpairs_rank, ot_rank

BATCH = 8
NS = (100, 500, 1000, 2000)      # paper used up to 5000 on GPU; CPU-scaled
OT_ITERS = 50
BWD_MAX_N = 1000                 # O(n^2) baselines w/ backprop OOM/time out
                                 # first — exactly the paper's point


def run():
  rng = np.random.default_rng(0)

  for n in NS:
    theta = jnp.array(rng.normal(size=(BATCH, n)).astype(np.float32))

    fns = {
        "soft_rank_q": jax.jit(lambda t: soft_rank(t, 1e-1, "l2")),
        "soft_rank_e": jax.jit(lambda t: soft_rank(t, 1e-1, "kl")),
        "allpairs": jax.jit(lambda t: allpairs_rank(t, 0.1)),
        f"ot_sinkhorn_t{OT_ITERS}": jax.jit(
            lambda t: ot_rank(t, 1e-2, num_iters=OT_ITERS)),
    }
    for name, fn in fns.items():
      us = time_fn(fn, theta, iters=3)
      emit(f"fig4_runtime/{name}/n={n}", us, f"batch={BATCH},fwd")

    grads = {
        "soft_rank_q": jax.jit(
            jax.grad(lambda t: jnp.sum(soft_rank(t, 1e-1, "l2") ** 2))),
        "allpairs": jax.jit(
            jax.grad(lambda t: jnp.sum(allpairs_rank(t, 0.1) ** 2))),
        f"ot_sinkhorn_t{OT_ITERS}": jax.jit(
            jax.grad(lambda t: jnp.sum(ot_rank(t, 1e-2, OT_ITERS) ** 2))),
    }
    for name, fn in grads.items():
      if n > BWD_MAX_N and name != "soft_rank_q":
        emit(f"fig4_runtime_bwd/{name}/n={n}", float("nan"),
             "skipped: O(n^2) baseline beyond CPU budget")
        continue
      us = time_fn(fn, theta, iters=3)
      emit(f"fig4_runtime_bwd/{name}/n={n}", us, f"batch={BATCH},fwd+bwd")


if __name__ == "__main__":
  run()

"""Serving engine vs per-request jit dispatch -> BENCH_serving.json.

The tentpole evidence for `repro.serving` (ISSUE 10): the same Zipf-ish
mixed-size request stream (n in [64, 4096], per-request eps) is served
three ways *in the same run*:

* ``serving/engine_stream`` — the micro-batching engine after
  plan-derived AOT warmup (shape buckets, dynamic batching, admission
  control), with p50/p95/p99 request latency, batch occupancy and
  padding-waste columns; ``aot_cache_miss_after_warmup`` must be 0 —
  the run *raises* otherwise, so CI can never upload an artifact whose
  warmup enumeration missed a bucket the stream hit;
* ``serving/per_request_jit_cold`` — one ``jax.jit`` dispatch per
  request, first pass: every novel (op, n) pays trace+compile on the
  request path (the status quo this subsystem replaces);
* ``serving/per_request_jit_warm`` — the same pass again with every
  shape already compiled: the strongest baseline (pure per-call
  dispatch + kernel time, no compiles).

The acceptance bar is the ``serving/speedup`` row: engine throughput
must be strictly higher than the *warm* per-request baseline
(``tools/check_backends.py --bench-serving`` gates this in CI).
``serving/shed_demo`` exercises both load-shedding paths (bounded-queue
rejection and deadline expiry in queue) so the `serving_shed` counters
land in the artifact's metrics snapshot.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import plan as plan_mod
from repro.core import soft_rank, soft_sort
from repro.obs import artifacts as obs_artifacts
from repro.obs import metrics
from repro.obs.timing import percentiles
from repro.serving import (
    EngineConfig,
    Request,
    ServingEngine,
    synthetic_stream,
)

OPS = ("soft_rank/l2/desc", "soft_sort/l2/desc")


@functools.lru_cache(maxsize=None)
def _baseline_fn(op_key: str):
  """One jitted unpadded operator per variant, stable identity so the
  warm pass reuses the jit cache (eps rides as a traced scalar)."""
  base = soft_rank if op_key.startswith("soft_rank") else soft_sort
  def fn(values, eps):
    return base(values, eps, "l2", "DESCENDING")
  return jax.jit(fn)


def _per_request_pass(requests) -> tuple[float, list[float]]:
  """Serve every request with one jit call each; (wall_s, latencies_us)."""
  lat = []
  t_pass = time.perf_counter()
  for req in requests:
    fn = _baseline_fn(req.op)
    t0 = time.perf_counter()
    jax.block_until_ready(
        fn(jnp.asarray(req.values)[None], jnp.float32(req.eps)))
    lat.append((time.perf_counter() - t0) * 1e6)
  return time.perf_counter() - t_pass, lat


def _hist_summary(name: str) -> dict:
  """Flatten one obs histogram family into avg/min/max columns."""
  out: dict = {}
  total_n, total_sum = 0, 0.0
  lo, hi = np.inf, -np.inf
  for h in metrics.histograms(name).values():
    total_n += h["count"]
    total_sum += h["sum"]
    if h["min"] is not None:
      lo, hi = min(lo, h["min"]), max(hi, h["max"])
  if total_n:
    out = {"avg": round(total_sum / total_n, 2),
           "min": round(float(lo), 2), "max": round(float(hi), 2),
           "count": total_n}
  return out


def _shed_demo() -> dict:
  """Exercise both shedding paths on a tiny engine (nothing executes,
  so no compiles); returns the typed-shed counts."""
  cfg = EngineConfig(ops=OPS, min_bucket=64, max_bucket=64, max_batch=4,
                     queue_capacity=4, max_wait_ms=1000.0)
  rng = np.random.default_rng(7)
  t0 = time.perf_counter()
  engine = ServingEngine(cfg)
  reqs = [Request(op=OPS[0], values=rng.standard_normal(33).astype(np.float32),
                  deadline_ms=0.0)
          for _ in range(8)]
  handles = [engine.submit(r) for r in reqs]
  queue_full = sum(1 for h in handles
                   if h.done() and h.result(0).status == "shed_queue_full")
  time.sleep(0.002)            # let the queued deadlines (0 ms) expire
  engine.step()
  deadline = sum(1 for h in handles
                 if h.done() and h.result(0).status == "shed_deadline")
  return {"wall_us": (time.perf_counter() - t0) * 1e6,
          "shed_queue_full": queue_full, "shed_deadline": deadline}


def run(smoke: bool = False, out_path: str = "BENCH_serving.json") -> dict:
  """Serve the stream three ways and write the schema-v1 artifact."""
  if smoke:
    n_max, max_batch, num_requests = 512, 8, 120
  else:
    n_max, max_batch, num_requests = 4096, 32, 600
  cfg = EngineConfig(ops=OPS, min_bucket=64, max_bucket=n_max,
                     max_batch=max_batch, max_wait_ms=2.0,
                     queue_capacity=max(num_requests, 256))
  engine = ServingEngine(cfg)

  t0 = time.perf_counter()
  compiled = engine.warmup()
  warmup_us = (time.perf_counter() - t0) * 1e6
  emit(f"serving/warmup/buckets={len(engine.policy.sizes)}"
       f"x{len(engine.policy.row_sizes)}", warmup_us,
       f"{compiled} executables AOT-compiled", collect=False)

  requests = synthetic_stream(num_requests, seed=0, ops=OPS,
                              n_min=64, n_max=n_max)
  t0 = time.perf_counter()
  results = engine.serve(requests)
  wall = time.perf_counter() - t0
  ok = [r for r in results if r.ok]
  if len(ok) != len(results):
    raise RuntimeError(f"engine shed {len(results) - len(ok)} requests in a "
                       f"no-deadline closed-loop run; expected none")
  p50, p95, p99 = percentiles([r.latency_us for r in ok])
  misses = sum(metrics.counters("aot_cache_miss").values())
  if misses:
    raise RuntimeError(
        f"aot_cache_miss={misses} after plan-derived warmup: the request "
        f"stream hit a bucket the warmup enumeration missed")
  engine_rps = len(ok) / max(wall, 1e-9)
  occupancy = _hist_summary("serving_batch_occupancy")
  waste = _hist_summary("serving_padding_waste")

  rows = [{
      "name": "serving/engine_stream",
      "wall_us": wall * 1e6, "req_per_s": round(engine_rps, 1),
      "requests": len(results), "ok": len(ok),
      "p50_us": p50, "p95_us": p95, "p99_us": p99,
      "max_batch": max_batch, "n_max": n_max, "ops": ",".join(OPS),
      "aot_cache_miss_after_warmup": misses,
      "warmup_compiles": compiled, "warmup_us": warmup_us,
  }, {
      "name": "serving/batch_occupancy",
      "wall_us": wall * 1e6,
      "occupancy_pct": occupancy, "padding_waste_pct": waste,
      "batches": occupancy.get("count", 0),
  }]
  emit("serving/engine_stream", wall * 1e6,
       f"{engine_rps:.0f} req/s; p50/p95/p99="
       f"{p50:.0f}/{p95:.0f}/{p99:.0f}us; "
       f"occupancy_avg={occupancy.get('avg', 0)}%", collect=False)

  # Per-request jit baselines over the identical stream.
  _baseline_fn.cache_clear()
  cold_wall, _ = _per_request_pass(requests)
  warm_wall, warm_lat = _per_request_pass(requests)
  wp50, wp95, wp99 = percentiles(warm_lat)
  cold_rps = len(requests) / max(cold_wall, 1e-9)
  warm_rps = len(requests) / max(warm_wall, 1e-9)
  rows.append({"name": "serving/per_request_jit_cold",
               "wall_us": cold_wall * 1e6, "req_per_s": round(cold_rps, 1),
               "requests": len(requests)})
  rows.append({"name": "serving/per_request_jit_warm",
               "wall_us": warm_wall * 1e6, "req_per_s": round(warm_rps, 1),
               "requests": len(requests),
               "p50_us": wp50, "p95_us": wp95, "p99_us": wp99})
  emit("serving/per_request_jit_cold", cold_wall * 1e6,
       f"{cold_rps:.0f} req/s (trace+compile on the request path)",
       collect=False)
  emit("serving/per_request_jit_warm", warm_wall * 1e6,
       f"{warm_rps:.0f} req/s (all shapes precompiled)", collect=False)

  rows.append({
      "name": "serving/speedup",
      "wall_us": wall * 1e6,
      "engine_req_per_s": round(engine_rps, 1),
      "warm_req_per_s": round(warm_rps, 1),
      "cold_req_per_s": round(cold_rps, 1),
      "speedup_vs_warm_x": round(engine_rps / max(warm_rps, 1e-9), 3),
      "speedup_vs_cold_x": round(engine_rps / max(cold_rps, 1e-9), 3),
  })
  emit("serving/speedup", wall * 1e6,
       f"engine is {engine_rps / max(warm_rps, 1e-9):.2f}x warm per-request "
       f"jit ({engine_rps / max(cold_rps, 1e-9):.2f}x cold)", collect=False)

  shed = _shed_demo()
  rows.append({"name": "serving/shed_demo", **shed})
  emit("serving/shed_demo", shed["wall_us"],
       f"queue_full={shed['shed_queue_full']} "
       f"deadline={shed['shed_deadline']}", collect=False)

  meta = obs_artifacts.collect_meta(
      smoke=smoke, suite="serving", ops=",".join(OPS),
      max_batch=max_batch, n_max=n_max, requests=num_requests,
      **plan_mod.plan_provenance())
  return obs_artifacts.write_bench_artifact(out_path, rows, meta)


if __name__ == "__main__":
  run()

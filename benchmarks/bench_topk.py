"""Paper Figure 4 (left/center): top-k classification loss quality.

CPU-scaled proxy of the CIFAR experiment: a 2-layer MLP on a synthetic
cluster-classification task (n in {10, 100} classes), trained with the
cross-entropy baseline, our soft top-k rank losses (Q and E), and the
All-pairs baseline.  Reproduced claim: the soft top-k losses reach accuracy
comparable to cross-entropy / OT at far lower cost than O(n^2) methods.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import soft_topk_loss, topk_accuracy
from repro.core.baselines import allpairs_rank

STEPS = 150
DIM = 32
HID = 64


def make_data(rng, n_classes, n_per=40):
  centers = rng.normal(size=(n_classes, DIM)) * 2.0
  xs, ys = [], []
  for c in range(n_classes):
    xs.append(centers[c] + rng.normal(size=(n_per, DIM)))
    ys.append(np.full(n_per, c))
  x = np.concatenate(xs).astype(np.float32)
  y = np.concatenate(ys).astype(np.int32)
  perm = rng.permutation(len(x))
  return jnp.array(x[perm]), jnp.array(y[perm])


def mlp_init(key, n_classes):
  k1, k2 = jax.random.split(key)
  return {
      "w1": jax.random.normal(k1, (DIM, HID)) * (1 / np.sqrt(DIM)),
      "w2": jax.random.normal(k2, (HID, n_classes)) * (1 / np.sqrt(HID)),
  }


def mlp_apply(p, x):
  return jax.nn.relu(x @ p["w1"]) @ p["w2"]


def losses(n_classes):
  def xent(theta, y):
    return -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(theta), y[:, None], axis=1))

  def soft_q(theta, y):
    return soft_topk_loss(theta, y, 1, 1e-1, "l2")

  def soft_e(theta, y):
    return soft_topk_loss(theta, y, 1, 1e-1, "kl")

  def allpairs(theta, y):
    r = allpairs_rank(jax.nn.sigmoid(theta), 0.1)
    r_true = jnp.take_along_axis(r, y[:, None], axis=1)[:, 0]
    return jnp.mean(jax.nn.relu(r_true - 1))

  return {"cross_entropy": xent, "soft_topk_q": soft_q,
          "soft_topk_e": soft_e, "allpairs": allpairs}


def run():
  rng = np.random.default_rng(0)
  for n_classes in (10, 100):
    x, y = make_data(rng, n_classes)
    n_train = int(len(x) * 0.8)
    xtr, ytr, xte, yte = x[:n_train], y[:n_train], x[n_train:], y[n_train:]
    for name, loss_fn in losses(n_classes).items():
      params = mlp_init(jax.random.PRNGKey(0), n_classes)

      @jax.jit
      def step(p, lr=0.05):
        g = jax.grad(lambda q: loss_fn(mlp_apply(q, xtr), ytr))(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

      t0 = time.perf_counter()
      for _ in range(STEPS):
        params = step(params)
      jax.block_until_ready(params["w1"])
      dt = (time.perf_counter() - t0) / STEPS * 1e6
      acc = float(topk_accuracy(mlp_apply(params, xte), yte, 1))
      emit(f"fig4_topk/{name}/classes={n_classes}", dt,
           f"test_acc={acc:.3f}")


if __name__ == "__main__":
  run()

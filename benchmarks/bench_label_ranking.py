"""Paper §6.3 / Table 1: label ranking via soft Spearman correlation.

Synthetic label-ranking datasets (linear ground truth + observation noise,
mirroring the semi-synthetic regime of Hullermeier et al.): a linear model
trained with (a) the soft-rank Spearman loss (r_Q, r_E, and the appendix
r~_E variant) vs (b) the "No projection" ablation (squared loss directly
on scores).  Metric: Spearman's rho on held-out data.  Reproduced claim:
the soft-rank layer improves rho on most datasets.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (
    hard_rank, soft_rank, soft_rank_kl_direct, soft_spearman_loss,
    spearman_correlation)

STEPS = 200


def make_dataset(rng, d=16, n_labels=8, n=256, noise=0.5):
  w = rng.normal(size=(d, n_labels))
  x = rng.normal(size=(n, d)).astype(np.float32)
  scores = x @ w + noise * rng.normal(size=(n, n_labels))
  ranks = np.asarray(hard_rank(jnp.array(scores), "ASCENDING"))
  return jnp.array(x), jnp.array(ranks.astype(np.float32))


def train(loss_kind, x, ranks):
  d, n_labels = x.shape[1], ranks.shape[1]
  w = jnp.zeros((d, n_labels))

  def loss(w):
    theta = x @ w
    if loss_kind == "no_projection":
      return 0.5 * jnp.mean(jnp.sum((theta - ranks) ** 2, -1))
    if loss_kind == "soft_rank_q":
      return soft_spearman_loss(theta, ranks, 1.0, "l2")
    if loss_kind == "soft_rank_e":
      return soft_spearman_loss(theta, ranks, 1.0, "kl")
    if loss_kind == "kl_direct":
      r = soft_rank_kl_direct(theta, 1.0)
      return 0.5 * jnp.mean(jnp.sum((r - ranks) ** 2, -1))
    raise ValueError(loss_kind)

  g_fn = jax.jit(jax.grad(loss))
  lr = 0.02
  for _ in range(STEPS):
    w = w - lr * g_fn(w)
  return w


def run():
  rng = np.random.default_rng(0)
  for noise in (0.25, 1.0):
    x, ranks = make_dataset(rng, noise=noise)
    n_train = int(0.8 * x.shape[0])
    xtr, rtr = x[:n_train], ranks[:n_train]
    xte, rte = x[n_train:], ranks[n_train:]
    for kind in ("soft_rank_q", "soft_rank_e", "kl_direct",
                 "no_projection"):
      t0 = time.perf_counter()
      w = train(kind, xtr, rtr)
      dt = (time.perf_counter() - t0) / STEPS * 1e6
      pred = hard_rank(xte @ w, "ASCENDING")
      rho = float(jnp.mean(spearman_correlation(pred, rte)))
      emit(f"table1_label_ranking/{kind}/noise={noise}", dt,
           f"spearman_rho={rho:.3f}")


if __name__ == "__main__":
  run()

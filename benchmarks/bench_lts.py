"""Paper §6.4 / Figures 6-7: robust regression via soft least trimmed squares.

Fig. 6 reproduction: the soft-LTS objective interpolates between hard LTS
(eps -> 0) and least squares (eps -> inf) — we sweep eps and report the
objective's distance to each endpoint.

Fig. 7 proxy: R^2 on clean test data vs training-label outlier fraction,
for least squares (ridge), hard LTS, soft LTS (Q), and a Huber-style loss,
on synthetic linear data with injected label noise (y += N(0, 5*std)).
Reproduced claim: (soft) LTS degrades far more gracefully than LS as the
outlier fraction grows.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import soft_lts_loss

STEPS = 300
D = 16
N = 512


def make_data(rng, outlier_frac):
  w_true = rng.normal(size=D)
  x = rng.normal(size=(N, D)).astype(np.float32)
  y = x @ w_true + 0.1 * rng.normal(size=N)
  n_out = int(outlier_frac * N)
  idx = rng.choice(N, n_out, replace=False)
  y[idx] += rng.normal(size=n_out) * 5 * np.std(y)
  xte = rng.normal(size=(256, D)).astype(np.float32)
  yte = xte @ w_true
  return (jnp.array(x), jnp.array(y.astype(np.float32)),
          jnp.array(xte), jnp.array(yte.astype(np.float32)), w_true)


def fit(loss_kind, x, y, eps=1e-2, trim=0.3, lr=0.05):
  w = jnp.zeros(D)
  k = int(trim * x.shape[0])

  def loss(w):
    res = 0.5 * (y - x @ w) ** 2
    if loss_kind == "least_squares":
      return jnp.mean(res) + 1e-4 * jnp.sum(w ** 2)
    if loss_kind == "huber":
      e = y - x @ w
      t = 1.345
      return jnp.mean(jnp.where(jnp.abs(e) < t, 0.5 * e ** 2,
                                t * (jnp.abs(e) - 0.5 * t)))
    if loss_kind == "hard_lts":
      return soft_lts_loss(res, k, 1e-7)
    if loss_kind == "soft_lts":
      return jnp.mean(soft_lts_loss(res, k, eps))
    raise ValueError(loss_kind)

  g = jax.jit(jax.grad(loss))
  for _ in range(STEPS):
    w = w - lr * g(w)
  return w


def r2(w, xte, yte):
  pred = xte @ w
  ss_res = jnp.sum((yte - pred) ** 2)
  ss_tot = jnp.sum((yte - jnp.mean(yte)) ** 2)
  return float(1 - ss_res / ss_tot)


def run():
  rng = np.random.default_rng(0)

  # --- Fig. 6: interpolation between LTS and LS ---
  x, y, xte, yte, _ = make_data(rng, 0.2)
  res = 0.5 * (y - x @ jnp.zeros(D)) ** 2
  k = int(0.3 * N)
  hard = float(soft_lts_loss(res, k, 1e-7))
  ls = float(jnp.mean(res))
  for eps in (1e-4, 1e-2, 1.0, 1e2, 1e5):
    v = float(jnp.mean(soft_lts_loss(res, k, eps)))
    frac = (v - hard) / max(ls - hard, 1e-9)
    emit(f"fig6_interpolation/eps={eps:g}", 0.0,
         f"objective={v:.4f},frac_to_LS={frac:.3f}")

  # --- Fig. 7: robustness vs outlier fraction ---
  for frac in (0.0, 0.1, 0.2, 0.3, 0.4):
    x, y, xte, yte, _ = make_data(rng, frac)
    for kind in ("least_squares", "huber", "hard_lts", "soft_lts"):
      t0 = time.perf_counter()
      w = fit(kind, x, y)
      dt = (time.perf_counter() - t0) / STEPS * 1e6
      emit(f"fig7_robust_regression/{kind}/outliers={frac}", dt,
           f"r2={r2(w, xte, yte):.3f}")


if __name__ == "__main__":
  run()

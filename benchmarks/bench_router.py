"""Framework-level benchmark: MoE router throughput.

Not a paper table — this measures the paper technique where the framework
actually runs it: soft-top-k routing over (tokens x experts) logits, in the
three implementations (sequential lax PAV, vectorized minimax closed form,
Pallas kernel in interpret mode), against the standard softmax-top-k
router.  Derived column reports tokens/second.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import soft_topk_mask
from repro.kernels.ops import soft_topk_gates


def run():
  rng = np.random.default_rng(0)
  for (t, e, k) in [(4096, 8, 2), (4096, 64, 6)]:
    logits = jnp.array(rng.normal(size=(t, e)).astype(np.float32))

    def softmax_topk(lg):
      probs = jax.nn.softmax(lg, -1)
      topv = jax.lax.top_k(probs, k)[0]
      return jnp.where(probs >= topv[..., -1:], probs, 0.0)

    fns = {
        "softmax_topk": jax.jit(softmax_topk),
        "soft_topk_minimax": jax.jit(
            lambda lg: soft_topk_mask(lg, k, 1.0, impl="minimax")),
        "soft_topk_lax_pav": jax.jit(
            lambda lg: soft_topk_mask(lg, k, 1.0, impl="lax")),
        "soft_topk_pallas": jax.jit(
            lambda lg: soft_topk_gates(lg, k, 1.0)),
    }
    for name, fn in fns.items():
      us = time_fn(fn, logits)
      emit(f"router/{name}/tokens={t},experts={e},k={k}", us,
           f"tokens_per_s={t / (us * 1e-6):.0f}")

    # backward (the differentiable-routing selling point)
    for name, base in [("soft_topk_minimax", "minimax"),
                       ("soft_topk_lax_pav", "lax")]:
      fn = jax.jit(jax.grad(
          lambda lg: jnp.sum(soft_topk_mask(lg, k, 1.0, impl=base) ** 2)))
      us = time_fn(fn, logits)
      emit(f"router_bwd/{name}/tokens={t},experts={e},k={k}", us,
           f"tokens_per_s={t / (us * 1e-6):.0f}")


if __name__ == "__main__":
  run()

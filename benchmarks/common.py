"""Benchmark utilities: timing, CSV emission, structured result capture.

Timing is delegated to ``repro.obs.timing`` (the one wall-clock
implementation shared with the launch drivers).  ``emit`` keeps the legacy
``name,us_per_call,derived`` CSV row on stdout *and* captures a structured
record into a process-global collector; ``benchmarks/run.py`` drains the
collector into a schema-v1 ``BENCH_*.json`` artifact via
``repro.obs.artifacts`` (see docs/BENCHMARKS.md for the schema).
"""

from __future__ import annotations

import math

from repro.obs import timing as obs_timing

# Structured records accumulated by ``emit``; drained by benchmarks/run.py.
_RESULTS: list[dict] = []


def time_fn(fn, *args, warmup: int = 2, iters: int = 5,
            name: str | None = None) -> float:
  """Median wall time per call in microseconds (jit-compiled fn)."""
  return obs_timing.time_fn(fn, *args, warmup=warmup, iters=iters, name=name)


def emit(name: str, us_per_call: float, derived: str = "",
         collect: bool = True, **fields) -> None:
  """Print the CSV row and (by default) capture a structured result.

  Non-finite ``us_per_call`` (NaN marks a skipped combination) is recorded
  as a ``skipped`` reason rather than a bogus timing, matching the artifact
  schema's result contract.
  """
  print(f"{name},{us_per_call:.1f},{derived}")
  if not collect:
    return
  rec: dict = {"name": name, **fields}
  if derived:
    rec["derived"] = derived
  if math.isfinite(us_per_call) and us_per_call >= 0:
    rec["wall_us"] = float(us_per_call)
  else:
    rec["skipped"] = derived or "not measured"
  _RESULTS.append(rec)


def drain_results() -> list[dict]:
  """Return and clear all structured records captured since the last drain."""
  out = list(_RESULTS)
  _RESULTS.clear()
  return out

"""Benchmark utilities: timing + CSV/JSON emission."""

from __future__ import annotations

import json
import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
  """Median wall time per call in microseconds (jit-compiled fn)."""
  for _ in range(warmup):
    jax.block_until_ready(fn(*args))
  times = []
  for _ in range(iters):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    times.append(time.perf_counter() - t0)
  times.sort()
  return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
  print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str, payload: dict) -> None:
  """Write a benchmark artifact (CI uploads BENCH_*.json files)."""
  with open(path, "w") as f:
    json.dump(payload, f, indent=2, sort_keys=True)
  print(f"wrote {path}")

"""Run every benchmark. One module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4_runtime,...] [--smoke]

Output: ``name,us_per_call,derived`` CSV on stdout, plus ``BENCH_*.json``
artifacts (currently ``BENCH_runtime.json`` from the dispatch-backend
sweep) in the working directory — CI uploads these.

``--smoke`` runs only the backend sweep at reduced sizes: a fast signal
that every registered backend still executes and emits the artifact.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    bench_label_ranking,
    bench_lts,
    bench_router,
    bench_runtime,
    bench_topk,
)

BENCHES = {
    "fig4_runtime": bench_runtime.run,        # Figure 4 (right)
    "fig4_topk": bench_topk.run,              # Figure 4 (left/center)
    "table1_label_ranking": bench_label_ranking.run,  # Table 1 / Figure 5
    "fig6_fig7_lts": bench_lts.run,           # Figures 6-7
    "router": bench_router.run,               # framework hot path
    "backend_sweep": bench_runtime.run_backend_sweep,  # BENCH_runtime.json
}


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--only", default=None,
                  help="comma-separated subset of " + ",".join(BENCHES))
  ap.add_argument("--smoke", action="store_true",
                  help="tiny backend sweep only; still writes BENCH_*.json")
  args = ap.parse_args()

  print("name,us_per_call,derived")
  if args.smoke:
    bench_runtime.run_backend_sweep(smoke=True)
    return

  names = args.only.split(",") if args.only else list(BENCHES)
  failed = []
  for name in names:
    try:
      BENCHES[name]()
    except Exception:  # keep the harness going; report at the end
      failed.append(name)
      traceback.print_exc(file=sys.stderr)
  if failed:
    print(f"FAILED: {failed}", file=sys.stderr)
    raise SystemExit(1)


if __name__ == "__main__":
  main()

"""Run every benchmark. One module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4_runtime,...] [--smoke]

Output: ``name,us_per_call,derived`` CSV on stdout, plus structured
``BENCH_*.json`` artifacts (schema ``repro.bench/v1``, see
docs/BENCHMARKS.md) in the working directory — CI validates and uploads
these:

* ``BENCH_runtime.json`` — the dispatch-backend sweep (fwd / fwd+bwd
  us/call per ``(regularization, backend, n, batch)`` cell), emitted by
  both the full run and ``--smoke``;
* ``BENCH_depth_curve.json`` — the O(n)-depth ("lax") vs O(log n)-depth
  ("scan") isotonic-solve curve across n with per-n speedups, emitted by
  both the full run and ``--smoke``;
* ``BENCH_projection.json`` — fused vs composed projection-pipeline
  e2e fwd / fwd+bwd timings with per-cell speedups and solver share,
  emitted by both the full run and ``--smoke``;
* ``BENCH_serving.json`` — the `repro.serving` engine vs per-request
  jit dispatch over the same mixed-size request stream (throughput,
  p50/p95/p99 latency, batch occupancy, shed demo), emitted by both the
  full run and ``--smoke``;
* ``BENCH_figures.json`` — every other paper-figure/table benchmark row,
  emitted by the full run.

Both artifacts embed the ``repro.obs`` metrics snapshot (per-backend
dispatch-resolution counters, shape buckets, trace-cache counts) taken at
write time, plus provenance meta (git sha, platform, jax version).

``--smoke`` runs only the backend sweep, depth curve, projection, and
serving suites at reduced sizes (n=1024 included so the scan-vs-lax and
fused-vs-composed speedup evidence survives the cut): a fast signal that
every registered backend still executes and emits schema-valid artifacts.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    bench_label_ranking,
    bench_lts,
    bench_projection,
    bench_router,
    bench_runtime,
    bench_serving,
    bench_topk,
    common,
)
from repro import plan as plan_mod
from repro.obs import artifacts as obs_artifacts
from repro.obs import metrics as obs_metrics

BENCHES = {
    "fig4_runtime": bench_runtime.run,        # Figure 4 (right)
    "fig4_topk": bench_topk.run,              # Figure 4 (left/center)
    "table1_label_ranking": bench_label_ranking.run,  # Table 1 / Figure 5
    "fig6_fig7_lts": bench_lts.run,           # Figures 6-7
    "router": bench_router.run,               # framework hot path
    "backend_sweep": bench_runtime.run_backend_sweep,  # BENCH_runtime.json
    "depth_curve": bench_runtime.run_depth_curve,      # BENCH_depth_curve.json
    "projection": bench_projection.run,                # BENCH_projection.json
    "serving": bench_serving.run,                      # BENCH_serving.json
}


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--only", default=None,
                  help="comma-separated subset of " + ",".join(BENCHES))
  ap.add_argument("--smoke", action="store_true",
                  help="tiny backend sweep + depth curve only; still writes "
                       "BENCH_*.json")
  args = ap.parse_args()

  # Start each harness invocation from a clean registry so artifact metrics
  # describe exactly this run, not whatever imported us earlier.
  obs_metrics.reset()

  print("name,us_per_call,derived")
  if args.smoke:
    bench_runtime.run_backend_sweep(smoke=True)
    bench_runtime.run_depth_curve(smoke=True)
    bench_projection.run(smoke=True)
    bench_serving.run(smoke=True)
    return

  names = args.only.split(",") if args.only else list(BENCHES)
  failed = []
  for name in names:
    try:
      BENCHES[name]()
    except Exception:  # keep the harness going; report at the end
      failed.append(name)
      traceback.print_exc(file=sys.stderr)

  results = common.drain_results()
  if results:
    obs_artifacts.write_bench_artifact(
        "BENCH_figures.json", results,
        obs_artifacts.collect_meta(suite="figures", smoke=False,
                                   only=args.only or "all",
                                   **plan_mod.plan_provenance()))
  if failed:
    print(f"FAILED: {failed}", file=sys.stderr)
    raise SystemExit(1)


if __name__ == "__main__":
  main()

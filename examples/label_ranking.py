"""Label ranking via the differentiable Spearman coefficient (paper §6.3).

Trains a linear model on synthetic label-ranking data with the soft-rank
Spearman loss, then ablates the soft-rank layer ("No projection" column of
the paper's Table 1) — the projection consistently improves held-out rho.

  PYTHONPATH=src python examples/label_ranking.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    hard_rank, soft_spearman_loss, spearman_correlation)


def make_dataset(rng, d=20, n_labels=10, n=512, noise=0.75):
  w = rng.normal(size=(d, n_labels))
  x = rng.normal(size=(n, d)).astype(np.float32)
  scores = x @ w + noise * rng.normal(size=(n, n_labels))
  ranks = np.asarray(hard_rank(jnp.array(scores), "ASCENDING"))
  return jnp.array(x), jnp.array(ranks.astype(np.float32))


def train(x, ranks, use_projection: bool, steps=300, lr=0.02):
  w = jnp.zeros((x.shape[1], ranks.shape[1]))

  def loss(w):
    theta = x @ w
    if use_projection:
      return soft_spearman_loss(theta, ranks, 1.0)
    return 0.5 * jnp.mean(jnp.sum((theta - ranks) ** 2, -1))

  g = jax.jit(jax.grad(loss))
  for _ in range(steps):
    w = w - lr * g(w)
  return w


def main():
  rng = np.random.default_rng(0)
  x, ranks = make_dataset(rng)
  n_tr = int(0.8 * len(x))
  for use_proj in (True, False):
    w = train(x[:n_tr], ranks[:n_tr], use_proj)
    pred = hard_rank(x[n_tr:] @ w, "ASCENDING")
    rho = float(jnp.mean(spearman_correlation(pred, ranks[n_tr:])))
    name = "soft-rank layer (r_Q)" if use_proj else "no projection"
    print(f"{name:24s} held-out Spearman rho = {rho:.4f}")


if __name__ == "__main__":
  main()

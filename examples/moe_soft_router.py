"""MoE language model with the paper's soft-top-k router, end to end.

Trains a small MoE LM twice — once with the standard softmax-top-k router
and once with the projection-based soft-top-k router (dense gradients to
every expert logit) — then serves a few greedy generations from the
soft-routed model.  Reports loss and expert load balance (coefficient of
variation of expert loads; lower = better balanced).

  PYTHONPATH=src python examples/moe_soft_router.py
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import pipeline_for_arch
from repro.launch import steps as ST
from repro.models import transformer as T
from repro.optim import adamw


def make_cfg(router: str) -> ArchConfig:
  return ArchConfig(
      name=f"moe-{router}", family="moe", num_layers=4, d_model=128,
      num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=4096,
      block_cycle=("moe",), num_experts=8, experts_per_token=2,
      moe_d_ff=128, router=router, router_eps=1.0, moe_group_size=64,
      dtype="float32", remat="none", q_chunk=64, kv_chunk=64,
      xent_chunk=64)


def expert_load_cv(cfg, params, batch):
  """Coefficient of variation of expert dispatch counts (balance metric)."""
  from repro.models import layers as L
  from repro.models.moe import _dispatch_mask, _router_weights
  x, _ = T._embed_inputs(cfg, params, batch)
  lp = params["seg0"]["l0_moe"]
  h = L.norm_apply(jax.tree.map(lambda a: a[0], lp["norm1"]), x, cfg.norm)
  xt = h.reshape(-1, cfg.d_model)
  xg = xt.reshape(-1, cfg.moe_group_size, cfg.d_model)
  router = lp["ffn"]["router"][0]
  logits = jnp.einsum("gtd,de->gte", xg, router)
  w, _ = _router_weights(cfg, logits)
  capacity = int(np.ceil(cfg.moe_group_size * cfg.experts_per_token *
                         cfg.capacity_factor / cfg.num_experts))
  dispatch, _ = _dispatch_mask(w, cfg.experts_per_token, capacity)
  loads = jnp.sum(dispatch, axis=(0, 1, 3))
  return float(jnp.std(loads) / jnp.maximum(jnp.mean(loads), 1e-9))


def train_one(router: str, steps: int, batch_size: int, seq: int):
  cfg = make_cfg(router)
  pipe = pipeline_for_arch(cfg, batch_size, seq, seed=0)
  params = T.init_params(cfg, jax.random.PRNGKey(0))
  opt_cfg = adamw.AdamWConfig(lr=1e-3)
  opt = ST.init_opt_state(cfg, opt_cfg, params)
  step_fn = jax.jit(ST.make_train_step(cfg, opt_cfg))
  batch = None
  for step in range(steps):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
    params, opt, m = step_fn(params, opt, batch)
    if step % 10 == 0:
      print(f"  [{router}] step {step:3d} loss {float(m['loss']):.4f} "
            f"aux {float(m['aux_loss']):.3f}")
  cv = expert_load_cv(cfg, params, batch)
  return cfg, params, float(m["loss"]), cv


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--steps", type=int, default=40)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=64)
  args = ap.parse_args()

  results = {}
  for router in ("softmax_topk", "soft_topk"):
    print(f"[moe] training with router={router}")
    cfg, params, loss, cv = train_one(router, args.steps, args.batch,
                                      args.seq)
    results[router] = (loss, cv)
    if router == "soft_topk":
      # quick greedy generation from the soft-routed model
      prompt = jnp.zeros((2, 16), jnp.int32)
      logits, caches = jax.jit(
          lambda p, b: T.forward_prefill(cfg, p, b, 32))(
              params, {"tokens": prompt, "targets": prompt})
      dec = jax.jit(lambda p, c, t, pos: T.forward_decode(cfg, p, c, t, pos))
      toks = []
      tok = jnp.argmax(logits, -1)
      for i in range(8):
        toks.append(np.asarray(tok))
        logits, caches = dec(params, caches, tok, jnp.int32(16 + i))
        tok = jnp.argmax(logits, -1)
      print("  [soft_topk] sample generation:", np.stack(toks, 1)[0].tolist())

  print("\nrouter comparison (lower is better):")
  for router, (loss, cv) in results.items():
    print(f"  {router:14s} final-loss {loss:.4f}   expert-load CV {cv:.3f}")


if __name__ == "__main__":
  main()

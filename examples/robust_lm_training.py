"""Robust LM pretraining with soft least-trimmed-squares token losses.

The paper's §6.4 application lifted to language modeling: a fraction of
training targets is corrupted (label noise); the soft-LTS loss soft-sorts
per-token losses and down-weights the largest ones, so corrupted tokens
stop dominating the gradient.  We train the same llama-family model with
and without trimming and compare the loss ON CLEAN TOKENS (the pipeline
exposes the corruption mask, used for evaluation only).

CPU demo (default ~20M params, a few minutes):
  PYTHONPATH=src python examples/robust_lm_training.py

Full recipe (~100M params, few hundred steps — sized for a real chip):
  PYTHONPATH=src python examples/robust_lm_training.py --full --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import pipeline_for_arch
from repro.launch import steps as ST
from repro.models import transformer as T
from repro.optim import adamw


def make_cfg(full: bool, trim: float) -> ArchConfig:
  if full:
    dims = dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                head_dim=64, d_ff=2048, vocab_size=32000)   # ~100M params
  else:
    dims = dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                head_dim=64, d_ff=1024, vocab_size=8192)    # ~20M params
  return ArchConfig(
      name="robust-lm", family="dense", block_cycle=("dense",),
      mlp_variant="swiglu", dtype="float32", remat="none",
      loss_trim_fraction=trim, loss_trim_eps=1e-2,
      q_chunk=128, kv_chunk=128, xent_chunk=128, **dims)


def run(trim: float, args) -> list[float]:
  cfg = make_cfg(args.full, trim)
  pipe = pipeline_for_arch(cfg, args.batch, args.seq, seed=0,
                           corrupt_fraction=args.corrupt)
  params = T.init_params(cfg, jax.random.PRNGKey(0))
  opt_cfg = adamw.AdamWConfig(lr=1e-3)
  opt = ST.init_opt_state(cfg, opt_cfg, params)
  train_step = jax.jit(ST.make_train_step(cfg, opt_cfg))

  @jax.jit
  def clean_loss(params, batch, mask):
    tok, _ = T.forward_train(cfg, params, batch)
    keep = 1.0 - mask
    return jnp.sum(tok * keep) / jnp.maximum(jnp.sum(keep), 1)

  clean = []
  for step in range(args.steps):
    raw = pipe.batch_at(step)
    mask = jnp.asarray(raw.pop("corrupt_mask").astype(np.float32))
    batch = {k: jnp.asarray(v) for k, v in raw.items()}
    params, opt, m = train_step(params, opt, batch)
    if step % args.eval_every == 0 or step == args.steps - 1:
      cl = float(clean_loss(params, batch, mask))
      clean.append(cl)
      print(f"  step {step:4d}  train {float(m['loss']):.4f}  "
            f"clean-token {cl:.4f}")
  return clean


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--full", action="store_true")
  ap.add_argument("--steps", type=int, default=60)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=128)
  ap.add_argument("--corrupt", type=float, default=0.25)
  ap.add_argument("--trim", type=float, default=0.25)
  ap.add_argument("--eval-every", type=int, default=10)
  args = ap.parse_args()

  print(f"[robust-lm] corruption={args.corrupt:.0%}  "
        f"({'~100M' if args.full else '~20M'} params)")
  print("[robust-lm] baseline (no trimming):")
  t0 = time.time()
  base = run(0.0, args)
  print("[robust-lm] soft-LTS trimming "
        f"(trim={args.trim:.0%}, paper §6.4):")
  trimmed = run(args.trim, args)
  print(f"\nclean-token loss:  baseline {base[-1]:.4f}  "
        f"vs soft-LTS {trimmed[-1]:.4f}  "
        f"(lower is better; {time.time()-t0:.0f}s total)")


if __name__ == "__main__":
  main()

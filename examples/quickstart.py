"""Quickstart: fast differentiable sorting and ranking in 2 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    soft_rank, soft_sort, soft_topk_mask, soft_quantile, spearman_correlation)

theta = jnp.array([2.9, 0.1, 1.2])

# --- the paper's Figure-1 example -----------------------------------------
print("theta         =", theta)
print("soft_rank eps=1 (Q):", soft_rank(theta, 1.0))        # == hard ranks
print("soft_rank eps=10   :", soft_rank(theta, 10.0))       # softened
print("soft_sort eps=0.1  :", soft_sort(theta, 0.1))

# --- everything is differentiable (exact O(n) Jacobian products) ----------
# (at eps=10 the ranks are genuinely soft, so the Jacobian is non-trivial)
loss = lambda t: jnp.sum(soft_rank(t, 10.0) * jnp.array([1.0, 0.0, 0.0]))
print("d rank_0 / d theta =", jax.grad(loss)(theta))

# --- entropic regularization (paper's E variant) ---------------------------
print("soft_rank KL       :", soft_rank(theta, 1.0, regularization="kl"))

# --- differentiable top-k and quantiles ------------------------------------
scores = jnp.array([3.0, 1.0, 2.0, 0.0, -1.0])
print("soft top-2 mask    :", soft_topk_mask(scores, 2, 0.5))
x = jax.random.normal(jax.random.PRNGKey(0), (999,))
print("soft median        :", soft_quantile(x, 0.5, 0.01))

# --- works under jit / vmap / grad, batched on the last axis ---------------
batch = jax.random.normal(jax.random.PRNGKey(1), (4, 10))
ranks = jax.jit(lambda b: soft_rank(b, 0.1))(batch)
print("batched ranks shape:", ranks.shape)
print("spearman(batch[0], batch[0]) =",
      spearman_correlation(ranks[0], ranks[0]))

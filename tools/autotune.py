#!/usr/bin/env python3
"""Derive the packaged default ExecutionPlan from measured BENCH sweeps.

  PYTHONPATH=src python tools/autotune.py \
      [--bench BENCH_runtime.json] [--bench-projection BENCH_projection.json] \
      [--out src/repro/plan/default_plan.json] [--run | --smoke] [--dry-run]

Turns the committed benchmark trajectory into the committed
``default_plan.json`` that ``auto`` dispatch resolves through
(``repro.plan``): for every measured ``(regularization, n, batch)`` cell
the winning backend (lowest end-to-end fwd+bwd time) becomes a plan-table
entry, bucketed by shape with boundaries at the geometric midpoints of the
measured grid and merged where adjacent buckets agree.  Every emitted rule
carries the BENCH row names that justify it (``evidence``), which
``tools/check_backends.py --plan`` re-verifies in CI — a plan entry no
timing row supports fails the build.

Derivation policy:

* Rules are keyed to the platform the artifact was measured on; on any
  other platform the packaged plan is silent and resolution falls through
  to the built-in plan (e.g. TPU -> pallas stays untouched by a CPU-derived
  plan).
* ``pallas`` is excluded as a candidate off-TPU: interpret-mode timings at
  small n say nothing about TPU hardware and extrapolate catastrophically.
* A winning ``minimax`` rule always gets the built-in ``rows * n^2``
  memory cap (``max_elems``) — the O(n^2) closed form must never be chosen
  into an OOM regardless of how well it timed at a small measured cell.
* Backward: the sweep's ``fwd_bwd_us`` timings exercised the default
  ``segscan`` VJP, so the plan pins it with those rows as evidence.

By default the plan is derived *from the committed artifacts* (so the
committed plan and the committed bench rows can never disagree); pass
``--run`` / ``--smoke`` to re-run the sweeps on the current host first.
"""

from __future__ import annotations

import argparse
import math
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

import json  # noqa: E402

from repro import plan as plan_mod  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402

DEFAULT_OUT = os.path.join("src", "repro", "plan", "default_plan.json")

REGS = ("l2", "kl")


def _load(path: str) -> dict:
  with open(path, encoding="utf-8") as f:
    return json.load(f)


def _finite(v) -> bool:
  return isinstance(v, (int, float)) and math.isfinite(v)


def _midpoint(lo: int, hi: int) -> int:
  """Geometric midpoint of two measured grid values (timings scale
  multiplicatively with size, so the crossover belongs on a log axis)."""
  return int(math.sqrt(lo * hi))


def _cells(results: list[dict], metric: str,
           exclude: set[str]) -> dict[tuple, dict[str, tuple]]:
  """{(reg, n, batch): {backend: (timing_us, row_name)}} for ran rows."""
  out: dict[tuple, dict[str, tuple]] = {}
  for r in results:
    if r.get("skipped") or not _finite(r.get(metric)):
      continue
    backend, reg = r.get("backend"), r.get("regularization")
    if backend in exclude or reg not in REGS:
      continue
    key = (reg, r.get("n"), r.get("batch"))
    if None in key:
      continue
    cell = out.setdefault(key, {})
    # Keep the best (lowest) timing if a backend appears twice.
    if backend not in cell or r[metric] < cell[backend][0]:
      cell[backend] = (r[metric], r["name"])
  return out


def _bounds(values: list[int], i_lo: int, i_hi: int):
  """(min, max) bucket bounds covering grid values[i_lo..i_hi] inclusive,
  with open outer edges (the first bucket extrapolates down, the last up)
  and geometric-midpoint inner edges."""
  lo = None if i_lo == 0 else _midpoint(values[i_lo - 1], values[i_lo]) + 1
  hi = (None if i_hi == len(values) - 1
        else _midpoint(values[i_hi], values[i_hi + 1]))
  return lo, hi


def _derive_rules(kind: str, op: str, cells: dict[tuple, dict[str, tuple]],
                  platform: str) -> list[plan_mod.PlanRule]:
  """Winner-per-cell -> merged shape-bucket rules, per regularization.

  For each reg, decide the winner of every measured (n, batch) cell, merge
  consecutive n grid values whose per-batch winner maps agree, then within
  each n-bucket merge consecutive batches (rows == batch in the sweep,
  inputs are (batch, n)) that agree.
  """
  rules: list[plan_mod.PlanRule] = []
  for reg in REGS:
    ns = sorted({n for (r, n, b) in cells if r == reg})
    batches = sorted({b for (r, n, b) in cells if r == reg})
    if not ns:
      continue
    # winner[n][batch] = (backend, evidence_row)
    winner: dict[int, dict[int, tuple]] = {}
    for n in ns:
      for b in batches:
        cell = cells.get((reg, n, b))
        if not cell:
          continue
        best = min(cell, key=lambda k: cell[k][0])
        winner.setdefault(n, {})[b] = (best, cell[best][1])

    # Merge consecutive n values with identical per-batch winner maps.
    groups: list[tuple[int, int]] = []  # (i_lo, i_hi) into ns
    for i, n in enumerate(ns):
      sig = {b: w[0] for b, w in winner.get(n, {}).items()}
      prev_sig = ({b: w[0] for b, w in winner.get(ns[groups[-1][0]], {})
                   .items()} if groups else None)
      if groups and sig == prev_sig:
        groups[-1] = (groups[-1][0], i)
      else:
        groups.append((i, i))

    for i_lo, i_hi in groups:
      min_n, max_n = _bounds(ns, i_lo, i_hi)
      group_ns = ns[i_lo:i_hi + 1]
      bmap = winner.get(group_ns[0], {})
      gbatches = sorted(bmap)
      # Merge consecutive batches with the same winning backend.
      bgroups: list[tuple[int, int]] = []
      for j, b in enumerate(gbatches):
        if bgroups and bmap[b][0] == bmap[gbatches[bgroups[-1][0]]][0]:
          bgroups[-1] = (bgroups[-1][0], j)
        else:
          bgroups.append((j, j))
      for j_lo, j_hi in bgroups:
        backend = bmap[gbatches[j_lo]][0]
        min_rows, max_rows = ((None, None) if len(bgroups) == 1
                              else _bounds(gbatches, j_lo, j_hi))
        evidence = tuple(
            winner[n][b][1] for n in group_ns
            for b in gbatches[j_lo:j_hi + 1] if b in winner.get(n, {}))
        rules.append(plan_mod.PlanRule(
            kind, backend, op=op, regularization=reg, platform=platform,
            min_n=min_n, max_n=max_n, min_rows=min_rows, max_rows=max_rows,
            max_elems=(plan_mod.BUILTIN_MINIMAX_MAX_ELEMS
                       if backend == "minimax" else None),
            evidence=evidence))
  return rules


def build_plan(runtime_payload: dict,
               projection_payload: dict) -> plan_mod.ExecutionPlan:
  platform = runtime_payload.get("meta", {}).get("platform", "cpu")
  exclude = {"pallas"} if platform != "tpu" else set()

  sweep = [r for r in runtime_payload.get("results", [])
           if r.get("name", "").startswith("backend_sweep/")]
  fwd_cells = _cells(sweep, "fwd_bwd_us", exclude)
  rules = _derive_rules("forward", "isotonic", fwd_cells, platform)

  # The sweep's fwd+bwd timings ran the default segscan VJP end to end:
  # pin it, evidenced by one winning row per (reg, n).
  bwd_evidence = tuple(dict.fromkeys(
      min(cell.values(), key=lambda v: v[0])[1]
      for key, cell in sorted(fwd_cells.items(), key=str)
      if key[2] == min(b for (_, _, b) in fwd_cells)))
  if bwd_evidence:
    rules.append(plan_mod.PlanRule(
        "backward", "segscan", platform=platform, evidence=bwd_evidence))

  proj_cells = _cells(projection_payload.get("results", []),
                      "e2e_fwd_bwd_us", exclude=set())
  rules.extend(_derive_rules("projection", "projection", proj_cells,
                             platform))

  meta = {
      "generated_by": "tools/autotune.py",
      "platform": platform,
      "derived_from": {
          "runtime": runtime_payload.get("meta", {}).get("git_sha", "?"),
          "projection": projection_payload.get("meta", {}).get(
              "git_sha", "?"),
      },
      "cells": {"runtime": len(fwd_cells), "projection": len(proj_cells)},
  }
  plan = plan_mod.ExecutionPlan(name=f"autotuned-{platform}",
                                rules=tuple(rules), meta=meta)
  for rule in plan.rules:
    obs_metrics.counter_inc("autotune_rule", kind=rule.kind,
                            backend=rule.backend)
  return plan


def main(argv: list[str]) -> int:
  ap = argparse.ArgumentParser(
      description="derive default_plan.json from BENCH sweep artifacts")
  ap.add_argument("--bench", default="BENCH_runtime.json")
  ap.add_argument("--bench-projection", default="BENCH_projection.json")
  ap.add_argument("--out", default=DEFAULT_OUT)
  ap.add_argument("--run", action="store_true",
                  help="re-run the full sweeps on this host first")
  ap.add_argument("--smoke", action="store_true",
                  help="re-run the reduced (smoke) sweeps first")
  ap.add_argument("--dry-run", action="store_true",
                  help="print the derived plan JSON without writing")
  args = ap.parse_args(argv)

  if args.run or args.smoke:
    from benchmarks.bench_projection import run as run_projection
    from benchmarks.bench_runtime import run_backend_sweep
    run_backend_sweep(smoke=args.smoke, out_path=args.bench)
    run_projection(smoke=args.smoke, out_path=args.bench_projection)

  plan = build_plan(_load(args.bench), _load(args.bench_projection))
  if args.dry_run:
    print(plan.to_json())
    return 0
  plan.save(args.out)
  plan_mod.invalidate_default_plan_cache()
  print(f"autotune: wrote {args.out} — {len(plan.rules)} rules, "
        f"hash {plan.plan_hash()}")
  return 0


if __name__ == "__main__":
  raise SystemExit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Backend-registry gate: docs coverage + bench-artifact completeness.

  PYTHONPATH=src python tools/check_backends.py [--bench BENCH_runtime.json]

Two checks (the first always runs, the second only with ``--bench``):

1. **Docs coverage** — every backend key registered in
   ``repro.kernels.dispatch`` (forward AND backward registries, plus the
   ``auto`` aliases) must appear as an inline-code token in the README
   backend table and in ``docs/ARCHITECTURE.md``, so a new backend cannot
   ship undocumented and the docs cannot keep advertising a deleted one
   (documented-but-unregistered names fail too).

2. **Bench completeness** — the given ``BENCH_runtime.json`` must contain,
   for every registered concrete forward backend and both regularizations,
   at least one result row that actually ran (a finite ``*_us`` timing
   field — a row that was skipped everywhere does not count), so the CI
   perf trajectory can never silently lose a backend.

Exit status 0 = clean; 1 = problems (each printed on stderr).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ("README.md", os.path.join("docs", "ARCHITECTURE.md"))

_CODE_TOKEN_RE = re.compile(r"`\"?([a-z_]+)\"?`")


def _registered() -> tuple[set[str], set[str]]:
  from repro.kernels import dispatch as D
  fwd = set()
  for reg in ("l2", "kl"):
    fwd |= set(D.registered_backends("isotonic", reg))
  bwd = set()
  for reg in ("l2", "kl"):
    bwd |= set(D.registered_backward_backends("isotonic", reg))
  return fwd, bwd


def check_docs_coverage() -> list[str]:
  from repro.kernels import dispatch as D
  problems = []
  fwd, bwd = _registered()
  # "auto" is a registered alias in both selection chains even though it
  # never appears as a registry key.
  want = fwd | bwd | {"auto"}
  known = set(D.BACKENDS) | set(D.BWD_BACKENDS)
  for rel in DOC_FILES:
    path = os.path.join(REPO_ROOT, rel)
    with open(path, encoding="utf-8") as f:
      text = f.read()
    documented = set(_CODE_TOKEN_RE.findall(text))
    for backend in sorted(want - documented):
      problems.append(f"{rel}: registered backend {backend!r} is not "
                      f"documented (expected a `\"{backend}\"` or "
                      f"`{backend}` code token)")
    # Docs naming a backend that is neither registered nor a selection
    # alias are advertising something the registry cannot serve.
    stale = {b for b in documented & (known - want - {"auto"})}
    for backend in sorted(stale):
      problems.append(f"{rel}: documents backend {backend!r} which is not "
                      f"registered")
  return problems


def check_bench_artifact(path: str) -> list[str]:
  problems = []
  if not os.path.exists(path):
    return [f"{path}: artifact not found"]
  with open(path, encoding="utf-8") as f:
    payload = json.load(f)
  results = payload.get("results", [])
  fwd, _ = _registered()
  for backend in sorted(fwd):
    for reg in ("l2", "kl"):
      rows = [r for r in results
              if r.get("backend") == backend
              and r.get("regularization") == reg]
      if not rows:
        problems.append(f"{path}: no results for backend={backend!r} "
                        f"regularization={reg!r}")
        continue
      ran = [r for r in rows if any(
          k.endswith("_us") and isinstance(r[k], (int, float))
          for k in r)]
      if not ran:
        problems.append(f"{path}: backend={backend!r} "
                        f"regularization={reg!r} has only skipped rows "
                        f"({rows[0].get('skipped', '?')!r}) — at least one "
                        f"cell must actually run")
  return problems


def main(argv: list[str]) -> int:
  ap = argparse.ArgumentParser()
  ap.add_argument("--bench", default=None,
                  help="also assert BENCH_runtime.json covers every "
                       "registered backend with a real timing")
  args = ap.parse_args(argv)

  problems = check_docs_coverage()
  if args.bench:
    problems += check_bench_artifact(args.bench)
  for p in problems:
    print(p, file=sys.stderr)
  checked = "docs" + (f" + {args.bench}" if args.bench else "")
  print(f"check_backends: {checked}, {len(problems)} problems")
  return 1 if problems else 0


if __name__ == "__main__":
  raise SystemExit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Backend-registry gate: docs coverage + bench-artifact completeness.

  PYTHONPATH=src python tools/check_backends.py [--bench BENCH_runtime.json]
      [--bench-projection BENCH_projection.json]

Three checks (the first always runs, the others only with their flag):

1. **Docs coverage** — every backend key registered in
   ``repro.kernels.dispatch`` (forward AND backward registries, the
   projection-path registry, plus the ``auto`` aliases) must appear as an
   inline-code token in the README backend table and in
   ``docs/ARCHITECTURE.md``, so a new backend cannot ship undocumented and
   the docs cannot keep advertising a deleted one
   (documented-but-unregistered names fail too).

2. **Bench completeness** — the given ``BENCH_runtime.json`` must contain,
   for every registered concrete forward backend and both regularizations,
   at least one result row that actually ran (a finite ``*_us`` timing
   field — a row that was skipped everywhere does not count), so the CI
   perf trajectory can never silently lose a backend.

3. **Projection bench + regression guard** — the given
   ``BENCH_projection.json`` must contain a finite-timing row per
   registered projection path (``fused`` / ``composed``) and
   regularization, AND in every cell where both paths ran in the same
   artifact the fused e2e fwd+bwd time must not exceed the composed one:
   the fused pipeline being slower than the reference chain it replaces is
   a regression by definition and fails the build.

4. **Serving gate** (``--bench-serving BENCH_serving.json``) — the
   artifact must carry the engine-stream (with p99 latency), batch-
   occupancy, warm per-request-jit baseline and speedup rows, report
   zero ``aot_cache_miss`` after plan-derived warmup, and show engine
   throughput strictly above the warm per-request baseline measured in
   the same run.

5. **Plan evidence** (``--plan PLAN.json``) — the committed
   ``default_plan.json`` must load strictly (schema version, no unknown
   fields), every rule must reference a backend registered for its kind,
   a winning ``minimax`` rule must carry its ``max_elems`` memory cap, and
   every rule must cite at least one ``evidence`` row name that exists
   *with a finite timing* in ``--plan-bench`` / ``--plan-bench-projection``
   — so a stale or hand-edited plan (claiming measurements that were never
   made) fails the build.  NOTE: run this against the *committed* BENCH
   artifacts, before any smoke run overwrites them.

Exit status 0 = clean; 1 = problems (each printed on stderr).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ("README.md", os.path.join("docs", "ARCHITECTURE.md"))

_CODE_TOKEN_RE = re.compile(r"`\"?([a-z_]+)\"?`")


def _registered() -> tuple[set[str], set[str], set[str]]:
  # Importing repro.core.projection populates the ("projection", reg, path)
  # rows of the forward registry — kernels.dispatch alone only carries the
  # isotonic backends plus the projection *backward* table.
  import repro.core.projection  # noqa: F401
  from repro.kernels import dispatch as D
  fwd = set()
  for reg in ("l2", "kl"):
    fwd |= set(D.registered_backends("isotonic", reg))
  bwd = set()
  for reg in ("l2", "kl"):
    bwd |= set(D.registered_backward_backends("isotonic", reg))
  proj = set()
  for reg in ("l2", "kl"):
    proj |= set(D.registered_backends("projection", reg))
  return fwd, bwd, proj


def check_docs_coverage() -> list[str]:
  from repro.kernels import dispatch as D
  problems = []
  fwd, bwd, proj = _registered()
  # "auto" is a registered alias in both selection chains even though it
  # never appears as a registry key.
  want = fwd | bwd | proj | {"auto"}
  known = set(D.BACKENDS) | set(D.BWD_BACKENDS) | set(D.PROJECTION_PATHS)
  for rel in DOC_FILES:
    path = os.path.join(REPO_ROOT, rel)
    with open(path, encoding="utf-8") as f:
      text = f.read()
    documented = set(_CODE_TOKEN_RE.findall(text))
    for backend in sorted(want - documented):
      problems.append(f"{rel}: registered backend {backend!r} is not "
                      f"documented (expected a `\"{backend}\"` or "
                      f"`{backend}` code token)")
    # Docs naming a backend that is neither registered nor a selection
    # alias are advertising something the registry cannot serve.
    stale = {b for b in documented & (known - want - {"auto"})}
    for backend in sorted(stale):
      problems.append(f"{rel}: documents backend {backend!r} which is not "
                      f"registered")
  return problems


def check_bench_artifact(path: str) -> list[str]:
  problems = []
  if not os.path.exists(path):
    return [f"{path}: artifact not found"]
  with open(path, encoding="utf-8") as f:
    payload = json.load(f)
  results = payload.get("results", [])
  fwd, _, _ = _registered()
  for backend in sorted(fwd):
    for reg in ("l2", "kl"):
      rows = [r for r in results
              if r.get("backend") == backend
              and r.get("regularization") == reg]
      if not rows:
        problems.append(f"{path}: no results for backend={backend!r} "
                        f"regularization={reg!r}")
        continue
      ran = [r for r in rows if any(
          k.endswith("_us") and isinstance(r[k], (int, float))
          for k in r)]
      if not ran:
        problems.append(f"{path}: backend={backend!r} "
                        f"regularization={reg!r} has only skipped rows "
                        f"({rows[0].get('skipped', '?')!r}) — at least one "
                        f"cell must actually run")
  return problems


def _finite_timing(rec: dict) -> bool:
  return any(k.endswith("_us") and isinstance(rec[k], (int, float))
             for k in rec)


def check_projection_artifact(path: str) -> list[str]:
  """Projection-path completeness + fused-vs-composed regression guard."""
  problems = []
  if not os.path.exists(path):
    return [f"{path}: artifact not found"]
  with open(path, encoding="utf-8") as f:
    payload = json.load(f)
  results = payload.get("results", [])
  _, _, proj = _registered()
  for reg in ("l2", "kl"):
    # Per-path coverage: every registered projection path must have run.
    for p in sorted(proj):
      rows = [r for r in results
              if r.get("backend") == p and r.get("regularization") == reg
              and _finite_timing(r)]
      if not rows:
        problems.append(f"{path}: no ran results for projection path "
                        f"{p!r} regularization={reg!r}")
    # Regression guard: wherever both paths ran at the same (n, batch) in
    # this artifact, fused must not be slower on e2e fwd+bwd — the fused
    # pipeline exists solely to beat the composed chain it replaces.
    cells: dict[tuple, dict[str, dict]] = {}
    for r in results:
      if (r.get("regularization") == reg and _finite_timing(r)
          and r.get("backend") in ("fused", "composed")):
        cells.setdefault((r.get("n"), r.get("batch")),
                         {})[r["backend"]] = r
    for (n, batch), by_path in sorted(cells.items(), key=str):
      fused, composed = by_path.get("fused"), by_path.get("composed")
      if not (fused and composed):
        continue
      f_us = fused.get("e2e_fwd_bwd_us")
      c_us = composed.get("e2e_fwd_bwd_us")
      if not isinstance(f_us, (int, float)) or not isinstance(
          c_us, (int, float)):
        problems.append(f"{path}: projection cell reg={reg!r} n={n} "
                        f"b={batch} is missing 'e2e_fwd_bwd_us'")
        continue
      if f_us > c_us:
        problems.append(
            f"{path}: projection regression — fused e2e fwd+bwd "
            f"({f_us:.1f}us) slower than composed ({c_us:.1f}us) at "
            f"reg={reg!r} n={n} b={batch}")
  return problems


def check_serving_artifact(path: str) -> list[str]:
  """Serving-engine gate: required rows, warmup coverage, and the
  engine-beats-per-request-jit acceptance bar.

  The artifact must contain finite-timing ``serving/engine_stream``
  (with p99 latency), ``serving/batch_occupancy``,
  ``serving/per_request_jit_warm`` and ``serving/speedup`` rows;
  ``aot_cache_miss_after_warmup`` must be 0 (warmup enumerated every
  bucket the stream hit); and engine throughput must be *strictly*
  higher than the warm per-request-jit baseline measured in the same
  run — the engine existing and losing to ad-hoc dispatch is a
  regression by definition.
  """
  problems = []
  if not os.path.exists(path):
    return [f"{path}: artifact not found"]
  with open(path, encoding="utf-8") as f:
    payload = json.load(f)
  rows = {r.get("name"): r for r in payload.get("results", [])
          if isinstance(r, dict)}
  required = ("serving/engine_stream", "serving/batch_occupancy",
              "serving/per_request_jit_warm", "serving/speedup")
  for name in required:
    if name not in rows or not _finite_timing(rows[name]):
      problems.append(f"{path}: missing ran row {name!r}")
  if problems:
    return problems
  stream = rows["serving/engine_stream"]
  if not isinstance(stream.get("p99_us"), (int, float)):
    problems.append(f"{path}: serving/engine_stream has no 'p99_us'")
  misses = stream.get("aot_cache_miss_after_warmup")
  if misses != 0:
    problems.append(f"{path}: aot_cache_miss_after_warmup={misses!r} — "
                    f"plan-derived warmup must cover every bucket the "
                    f"request stream hits")
  speed = rows["serving/speedup"]
  engine_rps = speed.get("engine_req_per_s")
  warm_rps = speed.get("warm_req_per_s")
  if not isinstance(engine_rps, (int, float)) or not isinstance(
      warm_rps, (int, float)):
    problems.append(f"{path}: serving/speedup is missing "
                    f"'engine_req_per_s'/'warm_req_per_s'")
  elif engine_rps <= warm_rps:
    problems.append(
        f"{path}: serving regression — engine throughput "
        f"({engine_rps:.1f} req/s) does not beat per-request jit "
        f"({warm_rps:.1f} req/s) on the same stream")
  return problems


def _evidenced_names(paths: list[str]) -> set[str]:
  """Row names with at least one finite timing across the artifacts."""
  names: set[str] = set()
  for path in paths:
    if not path or not os.path.exists(path):
      continue
    with open(path, encoding="utf-8") as f:
      payload = json.load(f)
    for r in payload.get("results", []):
      if isinstance(r, dict) and "name" in r and _finite_timing(r):
        names.add(r["name"])
  return names


def check_plan(plan_path: str, bench_paths: list[str]) -> list[str]:
  """Committed-plan gate: strict load, registered backends, evidence."""
  from repro import plan as plan_mod
  problems = []
  try:
    plan = plan_mod.load_plan(plan_path)
  except (OSError, ValueError) as e:
    return [f"{plan_path}: failed to load: {e}"]
  fwd, bwd, proj = _registered()
  by_kind = {"forward": fwd, "backward": bwd, "projection": proj}
  evidenced = _evidenced_names(bench_paths)
  missing_artifacts = [p for p in bench_paths if not os.path.exists(p)]
  for p in missing_artifacts:
    problems.append(f"{plan_path}: evidence artifact {p} not found")
  for i, rule in enumerate(plan.rules):
    where = f"{plan_path}: rule #{i} ({rule.kind} -> {rule.backend!r})"
    if rule.backend not in by_kind[rule.kind]:
      problems.append(
          f"{where}: backend not registered for kind {rule.kind!r} "
          f"(have {sorted(by_kind[rule.kind])})")
    if rule.backend == "minimax" and rule.max_elems is None:
      problems.append(f"{where}: minimax rule without a 'max_elems' memory "
                      f"cap — the O(n^2) form must stay size-capped")
    if not rule.evidence:
      problems.append(f"{where}: no 'evidence' timing rows — the committed "
                      f"plan must be measurement-backed (tools/autotune.py)")
      continue
    backed = [e for e in rule.evidence if e in evidenced]
    if not backed and not missing_artifacts:
      problems.append(
          f"{where}: none of its evidence rows "
          f"{list(rule.evidence)[:3]}{'...' if len(rule.evidence) > 3 else ''} "
          f"appear with a finite timing in {bench_paths}")
  return problems


def main(argv: list[str]) -> int:
  ap = argparse.ArgumentParser()
  ap.add_argument("--bench", default=None,
                  help="also assert BENCH_runtime.json covers every "
                       "registered backend with a real timing")
  ap.add_argument("--bench-projection", default=None,
                  help="also assert BENCH_projection.json covers every "
                       "projection path and that fused is not slower than "
                       "composed in the same run")
  ap.add_argument("--bench-serving", default=None,
                  help="also assert BENCH_serving.json has the engine / "
                       "baseline / occupancy rows, zero post-warmup AOT "
                       "misses, and engine throughput strictly above the "
                       "warm per-request-jit baseline")
  ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                  help="also validate a committed ExecutionPlan: strict "
                       "schema, registered backends, every rule evidenced "
                       "by a finite timing row")
  ap.add_argument("--plan-bench", default="BENCH_runtime.json",
                  help="artifact(s) plan evidence may cite (runtime)")
  ap.add_argument("--plan-bench-projection", default="BENCH_projection.json",
                  help="artifact(s) plan evidence may cite (projection)")
  args = ap.parse_args(argv)

  problems = check_docs_coverage()
  if args.bench:
    problems += check_bench_artifact(args.bench)
  if args.bench_projection:
    problems += check_projection_artifact(args.bench_projection)
  if args.bench_serving:
    problems += check_serving_artifact(args.bench_serving)
  if args.plan:
    problems += check_plan(args.plan,
                           [args.plan_bench, args.plan_bench_projection])
  for p in problems:
    print(p, file=sys.stderr)
  checked = "docs" + (f" + {args.bench}" if args.bench else "") + (
      f" + {args.bench_projection}" if args.bench_projection else "") + (
      f" + {args.bench_serving}" if args.bench_serving else "") + (
      f" + plan:{args.plan}" if args.plan else "")
  print(f"check_backends: {checked}, {len(problems)} problems")
  return 1 if problems else 0


if __name__ == "__main__":
  raise SystemExit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Docs gate: lightweight markdown lint + referenced-path existence check.

  python tools/check_docs.py [files...]     # default: README.md docs/*.md

Checks (zero third-party dependencies, so CI needs no extra installs):

1. **Markdown sanity** — balanced ``` code fences, LF line endings,
   trailing final newline, ATX headings followed by a space.
2. **Relative links resolve** — every ``[text](path)`` target that is not
   a URL or a pure anchor must exist relative to the referencing file.
3. **Code paths exist** — every repo-path-looking token
   (``src/...``, ``tests/...``, ``benchmarks/...``, ``docs/...``,
   ``examples/...``, ``tools/...``, ``.github/...``) mentioned anywhere
   in the docs must exist on disk, so the documentation can never name a
   module that a refactor deleted. Glob-y tokens (``BENCH_*.json``) are
   skipped.

Exit status 0 = clean; 1 = problems (each printed as ``file:line: msg``).
"""

from __future__ import annotations

import glob
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Repo-path-looking tokens: a known top-level directory followed by a
# plausible relative path with a file extension.
_PATH_RE = re.compile(
    r"(?<![\w/.])((?:src|tests|benchmarks|docs|examples|tools|\.github)"
    r"/[\w\-./]+\.[A-Za-z]{1,5})")

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

_FENCE_RE = re.compile(r"^(`{3,})")


def default_files() -> list[str]:
  files = [os.path.join(REPO_ROOT, "README.md")]
  files += sorted(glob.glob(os.path.join(REPO_ROOT, "docs", "**", "*.md"),
                            recursive=True))
  return [f for f in files if os.path.exists(f)]


def check_file(path: str) -> list[str]:
  problems: list[str] = []
  rel = os.path.relpath(path, REPO_ROOT)
  with open(path, "rb") as f:
    raw = f.read()
  if b"\r" in raw:
    problems.append(f"{rel}:1: CRLF line endings (use LF)")
  if raw and not raw.endswith(b"\n"):
    problems.append(f"{rel}:1: missing trailing newline")
  text = raw.decode("utf-8", errors="replace")
  lines = text.splitlines()

  in_fence = False
  fence_open_line = 0
  for i, line in enumerate(lines, 1):
    if _FENCE_RE.match(line.strip()):
      in_fence = not in_fence
      if in_fence:
        fence_open_line = i
      continue
    if in_fence:
      continue
    if line.startswith("#") and not re.match(r"^#{1,6} \S", line):
      problems.append(f"{rel}:{i}: malformed ATX heading: {line[:40]!r}")
    # Relative links must resolve (from the referencing file's directory).
    for m in _LINK_RE.finditer(line):
      target = m.group(1)
      if target.startswith(("http://", "https://", "mailto:", "#")):
        continue
      target = target.split("#")[0]
      if not target or "*" in target:
        continue
      resolved = os.path.normpath(os.path.join(os.path.dirname(path), target))
      if not os.path.exists(resolved):
        problems.append(f"{rel}:{i}: broken link target {target!r}")
    # Repo paths named in prose/tables/code spans must exist.
    for m in _PATH_RE.finditer(line):
      token = m.group(1).rstrip(".")
      if "*" in token:
        continue
      if not os.path.exists(os.path.join(REPO_ROOT, token)):
        problems.append(f"{rel}:{i}: references nonexistent path {token!r}")
  if in_fence:
    problems.append(f"{rel}:{fence_open_line}: unclosed code fence")
  return problems


def main(argv: list[str]) -> int:
  files = [os.path.abspath(a) for a in argv] if argv else default_files()
  if not files:
    print("check_docs: no markdown files found", file=sys.stderr)
    return 1
  problems: list[str] = []
  for path in files:
    problems += check_file(path)
  for p in problems:
    print(p, file=sys.stderr)
  print(f"check_docs: {len(files)} files, {len(problems)} problems")
  return 1 if problems else 0


if __name__ == "__main__":
  raise SystemExit(main(sys.argv[1:]))

"""Fused projection pipeline vs the composed reference (ISSUE 8 tentpole).

The ``fused`` path (one custom VJP around sort + isotonic solve + gather)
must be *indistinguishable* from the ``composed`` chain of differentiable
primitives — forward values and VJPs — across regularizations, weight
layouts (already-sorted, unsorted, batched) and tied inputs.  On top of
the equivalence contract: the exact-regime Lemma 3 guarantee must survive
the fusion, the fused backward must compile to zero scatters, the
``REPRO_PROJECTION`` escape hatch must reach the composed path, and the
observability counters must record what ran.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo
from repro.core import (
    SortContext, hard_rank, soft_rank, soft_sort)
from repro.core.permutations import (
    argsort_descending_fast, invert_permutation_fast)
from repro.core.projection import projection_permutahedron
from repro.kernels import dispatch
from repro.obs import metrics

rng = np.random.default_rng(7)


def _proj_loss(path, reg, z, w, **kwargs):
  out = projection_permutahedron(z, w, reg, path=path, **kwargs)
  return jnp.sum(out * jnp.cos(jnp.arange(out.size).reshape(out.shape)))


def _assert_paths_match(reg, z, w, **kwargs):
  """Forward values and (z, w) gradients agree between the two paths."""
  out_f = projection_permutahedron(z, w, reg, path="fused", **kwargs)
  out_c = projection_permutahedron(z, w, reg, path="composed", **kwargs)
  np.testing.assert_allclose(out_f, out_c, rtol=1e-5, atol=1e-5)
  gf = jax.grad(functools.partial(_proj_loss, "fused", reg, **kwargs),
                argnums=(0, 1))(z, w)
  gc = jax.grad(functools.partial(_proj_loss, "composed", reg, **kwargs),
                argnums=(0, 1))(z, w)
  for a, b in zip(gf, gc):
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Deterministic equivalence sweep (always runs, hypothesis or not)
# ---------------------------------------------------------------------------

# Values quantized to a 0.5 grid so ties are common — tie handling is
# exactly where a fused re-derivation of block structure could diverge
# from the composed chain.


def _tied(shape, seed):
  local = np.random.default_rng(seed)
  return jnp.array(
      (local.integers(-10, 11, size=shape) / 2).astype(np.float32))


@pytest.mark.parametrize("reg", ["l2", "kl"])
@pytest.mark.parametrize("w_mode", ["unsorted", "sorted", "batched"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fused_matches_composed(reg, w_mode, seed):
  n = 4 + 3 * seed
  kwargs = {}
  if w_mode == "batched":
    z = _tied((3, n), seed)
    w = _tied((3, n), seed + 100)
  else:
    z = _tied((n,), seed)
    if w_mode == "sorted":
      w = jnp.arange(n, 0, -1, dtype=jnp.float32)
      kwargs["w_is_sorted"] = True
    else:
      w = _tied((n,), seed + 100)
  if reg == "kl" and w_mode != "sorted":
    w = w / 4.0  # keep exp(w) well-conditioned in f32
  _assert_paths_match(reg, z, w, **kwargs)


# ---------------------------------------------------------------------------
# Property-based equivalence (hypothesis, when available)
# ---------------------------------------------------------------------------

try:
  from hypothesis import given, settings, strategies as st
  _HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extra not installed
  _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
  SETTINGS = dict(max_examples=25, deadline=None)

  tied_floats = st.integers(min_value=-10, max_value=10).map(
      lambda i: i / 2)
  vectors = st.lists(tied_floats, min_size=2, max_size=12)

  @given(vectors, vectors, st.sampled_from(["l2", "kl"]))
  @settings(**SETTINGS)
  def test_fused_matches_composed_unsorted_w(zv, wv, reg):
    n = min(len(zv), len(wv))
    z = jnp.array(np.asarray(zv[:n], np.float32))
    w = jnp.array(np.asarray(wv[:n], np.float32))
    if reg == "kl":
      w = w / 4.0
    _assert_paths_match(reg, z, w)

  @given(vectors, st.sampled_from(["l2", "kl"]))
  @settings(**SETTINGS)
  def test_fused_matches_composed_sorted_w(zv, reg):
    """w pre-sorted with the w_is_sorted guarantee (soft_rank's case)."""
    n = len(zv)
    z = jnp.array(np.asarray(zv, np.float32))
    w = jnp.arange(n, 0, -1, dtype=jnp.float32)
    _assert_paths_match(reg, z, w, w_is_sorted=True)

  @given(vectors, vectors, st.sampled_from(["l2", "kl"]))
  @settings(**SETTINGS)
  def test_fused_matches_composed_batched_w(zv, wv, reg):
    """Per-row weights: w carries the same batch shape as z."""
    n = min(len(zv), len(wv))
    z = jnp.stack([jnp.array(np.asarray(zv[:n], np.float32)),
                   jnp.array(np.asarray(zv[:n], np.float32)) * 0.5])
    w = jnp.stack([jnp.array(np.asarray(wv[:n], np.float32)),
                   jnp.array(np.asarray(wv[:n], np.float32))[::-1]])
    if reg == "kl":
      w = w / 4.0
    _assert_paths_match(reg, z, w)


# ---------------------------------------------------------------------------
# Exact regime (Lemma 3) survives the fusion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("reg", ["l2", "kl"])
def test_exact_regime_through_fused_path(reg):
  from repro.core import eps_min
  assert dispatch.resolve_projection(None) == "fused"
  n = 7
  local = np.random.default_rng(3)
  theta = jnp.array(local.normal(size=n).astype(np.float32)) * 2
  rho = jnp.arange(n, 0, -1).astype(jnp.float32)
  s_sorted = jnp.flip(jnp.sort(-theta))
  eps = float(eps_min(s_sorted, rho)) * 0.5
  ranks = soft_rank(theta, eps, reg)
  np.testing.assert_allclose(ranks, hard_rank(theta, "DESCENDING"),
                             atol=1e-3)


# ---------------------------------------------------------------------------
# Zero scatters in the fused backward's compiled HLO
# ---------------------------------------------------------------------------


def _opcode_count(text: str, opcode: str) -> int:
  return sum(1 for instrs in hlo.parse_computations(text).values()
             for i in instrs if i.opcode == opcode)


@pytest.mark.parametrize("reg", ["l2", "kl"])
def test_fused_backward_compiles_to_zero_scatters(reg):
  theta = jnp.array(rng.normal(size=(2, 32)).astype(np.float32))

  def f(t):
    return soft_rank(t, 0.1, reg, impl="scan")

  out, vjp = jax.vjp(f, theta)
  text = jax.jit(vjp).lower(out).compile().as_text()
  assert _opcode_count(text, "scatter") == 0, (
      "fused projection backward must be gather-only")


def test_fused_forward_compiles_to_zero_scatters():
  theta = jnp.array(rng.normal(size=(2, 32)).astype(np.float32))
  text = (jax.jit(lambda t: soft_rank(t, 0.1, "l2", impl="scan"))
          .lower(theta).compile().as_text())
  assert _opcode_count(text, "scatter") == 0


# ---------------------------------------------------------------------------
# Path selection: env escape hatch + precedence
# ---------------------------------------------------------------------------


def test_env_selects_composed(monkeypatch):
  monkeypatch.setenv(dispatch.PROJECTION_ENV_VAR, "composed")
  assert dispatch.resolve_projection(None) == "composed"
  # Explicit argument still wins over the environment.
  assert dispatch.resolve_projection("fused") == "fused"
  # And the composed path actually serves calls under the env override.
  theta = jnp.array(rng.normal(size=(3, 9)).astype(np.float32))
  r_env = soft_rank(theta, 0.5, "l2")
  monkeypatch.delenv(dispatch.PROJECTION_ENV_VAR)
  np.testing.assert_allclose(r_env, soft_rank(theta, 0.5, "l2"),
                             rtol=1e-5, atol=1e-5)


def test_env_rejects_unknown_path(monkeypatch):
  monkeypatch.setenv(dispatch.PROJECTION_ENV_VAR, "warp")
  with pytest.raises(ValueError, match="warp"):
    dispatch.resolve_projection(None)


# ---------------------------------------------------------------------------
# Observability: counters record what ran
# ---------------------------------------------------------------------------


def test_fused_calls_counter_increments():
  metrics.set_enabled(True)
  metrics.reset()
  try:
    theta = jnp.array(rng.normal(size=(2, 8)).astype(np.float32))
    soft_rank(theta, 0.5, "l2")
    assert metrics.counter_value("projection_fused_calls",
                                 regularization="l2") >= 1
  finally:
    metrics.set_enabled(None)
    metrics.reset()


def test_sort_context_reuse_counter():
  metrics.set_enabled(True)
  metrics.reset()
  try:
    theta = jnp.array(rng.normal(size=(2, 8)).astype(np.float32))
    ctx = SortContext(theta)
    r1 = soft_rank(theta, 0.5, "l2", sort_context=ctx)
    r2 = soft_rank(theta, 0.1, "l2", sort_context=ctx)
    assert metrics.counter_value("sort_reuse_miss",
                                 source="sort_context") == 1
    assert metrics.counter_value("sort_reuse_hit",
                                 source="sort_context") >= 1
    # The reused permutation must agree with a context-free call.
    np.testing.assert_allclose(r1, soft_rank(theta, 0.5, "l2"),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r2, soft_rank(theta, 0.1, "l2"),
                               rtol=1e-5, atol=1e-5)
  finally:
    metrics.set_enabled(None)
    metrics.reset()


def test_unbatched_w_cache_counter():
  metrics.set_enabled(True)
  metrics.reset()
  try:
    z = jnp.array(rng.normal(size=(4, 8)).astype(np.float32))
    w = jnp.array(rng.normal(size=8).astype(np.float32))
    projection_permutahedron(z, w, "l2")
    projection_permutahedron(z * 2, w, "l2")  # same eager concrete w
    assert metrics.counter_value("sort_reuse_hit", source="w_cache") >= 1
  finally:
    metrics.set_enabled(None)
    metrics.reset()


# ---------------------------------------------------------------------------
# SortContext equivalence including gradients
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("direction", ["ASCENDING", "DESCENDING"])
def test_sort_context_matches_plain_calls(direction):
  theta = jnp.array(rng.normal(size=(3, 10)).astype(np.float32))

  def with_ctx(t):
    ctx = SortContext(t)
    return (jnp.sum(soft_rank(t, 0.7, "l2", direction,
                              sort_context=ctx) ** 2)
            + jnp.sum(soft_sort(t, 0.7, "l2", direction,
                                sort_context=ctx) ** 2))

  def without_ctx(t):
    return (jnp.sum(soft_rank(t, 0.7, "l2", direction) ** 2)
            + jnp.sum(soft_sort(t, 0.7, "l2", direction) ** 2))

  np.testing.assert_allclose(with_ctx(theta), without_ctx(theta),
                             rtol=1e-5, atol=1e-5)
  np.testing.assert_allclose(jax.grad(with_ctx)(theta),
                             jax.grad(without_ctx)(theta),
                             rtol=1e-4, atol=1e-5)
  # Also under jit, where the context must be built inside the trace.
  np.testing.assert_allclose(jax.jit(with_ctx)(theta), without_ctx(theta),
                             rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# int32 permutation plumbing
# ---------------------------------------------------------------------------


def test_fast_sort_helpers_return_int32():
  x = jnp.array(rng.normal(size=(3, 17)).astype(np.float32))
  s, sigma = argsort_descending_fast(x)
  assert sigma.dtype == jnp.int32
  assert invert_permutation_fast(sigma).dtype == jnp.int32
  np.testing.assert_array_equal(
      np.take_along_axis(np.asarray(x), np.asarray(sigma), axis=-1),
      np.asarray(s))


# ---------------------------------------------------------------------------
# jit must not change fused results (custom_vjp u64-bitcast regression)
# ---------------------------------------------------------------------------

# Lowering a custom_vjp sub-jaxpr with global x64 off used to demote the
# packed sort's size-changing u32 -> u64 bitcast to a no-op, splitting it
# into independent word sorts: sorted values stayed correct while the
# permutation payload silently became identity, so every jitted fused
# projection un-permuted with the wrong sigma.  The fix hoists the sorts
# out of the custom_vjp (``_fused_entry``); these cases pin it down.


@pytest.mark.parametrize("reg", ["l2", "kl"])
@pytest.mark.parametrize("w_mode", ["unsorted", "sorted_hint", "batched"])
def test_fused_matches_eager_under_jit(reg, w_mode):
  local = np.random.default_rng(11)
  n = 8
  z = jnp.array(local.normal(size=(2, n)).astype(np.float32) * 50)
  kwargs = {}
  if w_mode == "batched":
    w = jnp.array(local.normal(size=(2, n)).astype(np.float32))
  elif w_mode == "sorted_hint":
    w = jnp.arange(n, 0, -1, dtype=jnp.float32)
    kwargs["w_is_sorted"] = True
  else:
    w = jnp.array(local.normal(size=(n,)).astype(np.float32))
  if reg == "kl":
    w = w / 4.0

  def f(z_, w_):
    return projection_permutahedron(z_, w_, reg, "lax", path="fused",
                                    **kwargs)

  eager = np.asarray(f(z, w))
  jitted = np.asarray(jax.jit(f)(z, w))
  np.testing.assert_array_equal(eager, jitted)
  composed = np.asarray(projection_permutahedron(z, w, reg, "lax",
                                                 path="composed", **kwargs))
  np.testing.assert_allclose(jitted, composed, rtol=1e-5, atol=1e-5)


def test_fused_jit_wide_range_ladder():
  """The serving pad construction's regime: a steep descending ladder
  appended to a small real prefix — jit and eager must agree bitwise."""
  z = jnp.array([[-4.08, 5.11, -0.84, -148.5, -292.9, -437.3, -581.7,
                  -726.1]], jnp.float32)
  w = jnp.array([[3., 2., 1., 0., -1., -2., -3., -4.]], jnp.float32)

  def f(z_, w_):
    return projection_permutahedron(z_, w_, "l2", "lax", path="fused",
                                    w_is_sorted=True)

  np.testing.assert_array_equal(np.asarray(f(z, w)),
                                np.asarray(jax.jit(f)(z, w)))


def test_fused_grad_matches_under_jit():
  local = np.random.default_rng(12)
  z = jnp.array(local.normal(size=(2, 9)).astype(np.float32) * 10)
  w = jnp.array(local.normal(size=(9,)).astype(np.float32))
  g = jax.grad(functools.partial(_proj_loss, "fused", "l2"), argnums=(0, 1))
  ge = g(z, w)
  gj = jax.jit(g)(z, w)
  for a, b in zip(ge, gj):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

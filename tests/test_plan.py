"""ExecutionPlan layer: unified precedence chain (all three decision
kinds), JSON round-trip + strict rejection, plan-pinned dispatch, and the
``--plan`` == ``use_plan`` counter-trace equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.projection  # noqa: F401  (populates projection registry)
from repro import plan as plan_mod
from repro.core.isotonic import isotonic_kl, isotonic_l2
from repro.core.operators import soft_rank, soft_sort
from repro.kernels import dispatch as D
from repro.obs import metrics

rng = np.random.default_rng(23)


@pytest.fixture(autouse=True)
def clean_state(monkeypatch):
  """No env overrides, no active plan, fresh metrics around every test."""
  for var in (D.ENV_VAR, D.BWD_ENV_VAR, D.PROJECTION_ENV_VAR):
    monkeypatch.delenv(var, raising=False)
  plan_mod.set_active_plan(None)
  metrics.set_enabled(True)
  metrics.reset()
  yield
  plan_mod.set_active_plan(None)
  metrics.set_enabled(None)
  metrics.reset()


# ---------------------------------------------------------------------------
# The unified precedence chain: arg > env > active plan > default plan.
# ---------------------------------------------------------------------------

# (kind, env var, expected default-plan route on cpu, active-plan backend,
#  env backend, explicit-arg backend).  The env/arg values are chosen to
# differ from the level below them so each hop is observable.
_CHAIN_CASES = [
    ("forward", D.ENV_VAR, "lax", "scan", "minimax", "pallas"),
    ("backward", D.BWD_ENV_VAR, "segscan", "scatter", "segscan", "scatter"),
    ("projection", D.PROJECTION_ENV_VAR, "fused", "composed", "fused",
     "composed"),
]


@pytest.mark.parametrize(
    "kind,env_var,default_backend,plan_backend,env_backend,arg_backend",
    _CHAIN_CASES, ids=[c[0] for c in _CHAIN_CASES])
def test_precedence_chain_arg_env_active_default(
    monkeypatch, kind, env_var, default_backend, plan_backend, env_backend,
    arg_backend):
  op = "projection" if kind == "projection" else "isotonic"

  def res(request=None):
    return D.resolve(kind, op, "l2", request, shape=(4, 9), platform="cpu")

  # Level 4: no arg, no env, no active plan -> the committed default plan.
  assert res() == default_backend
  # Level 3: an active plan overrides the default plan.
  pinned = plan_mod.ExecutionPlan(
      name="pinned", rules=(plan_mod.PlanRule(kind, plan_backend),))
  with plan_mod.use_plan(pinned):
    assert res() == plan_backend
    # Level 2: the environment overrides the active plan.
    monkeypatch.setenv(env_var, env_backend)
    assert res() == env_backend
    # Level 1: an explicit argument overrides everything.
    assert res(arg_backend) == arg_backend
  # "auto" (arg or env) falls through to the plan chain, not to a backend.
  monkeypatch.setenv(env_var, "auto")
  assert res("auto") == default_backend


def test_per_call_plan_beats_default_but_not_arg_or_env(monkeypatch):
  pinned = plan_mod.ExecutionPlan(
      name="arg-plan", rules=(plan_mod.PlanRule("forward", "lax"),))
  assert D.resolve_backend("isotonic", "l2", None, shape=(4, 9),
                           platform="cpu", plan=pinned) == "lax"
  monkeypatch.setenv(D.ENV_VAR, "minimax")
  assert D.resolve_backend("isotonic", "l2", None, shape=(4, 9),
                           platform="cpu", plan=pinned) == "minimax"
  assert D.resolve_backend("isotonic", "l2", "scan", shape=(4, 9),
                           platform="cpu", plan=pinned) == "scan"


def test_use_backend_shim_layers_on_plan_chain():
  """The deprecated shims are plan rules now: same chain, same semantics."""
  assert D.get_default_backend() == "auto"
  with D.use_backend("minimax"):
    assert D.get_default_backend() == "minimax"
    assert D.resolve_backend("isotonic", "l2", None, shape=(4, 1000),
                             platform="cpu") == "minimax"
    # Explicit arg still beats the shim.
    assert D.resolve_backend("isotonic", "l2", "lax", shape=(4, 9),
                             platform="cpu") == "lax"
  assert D.get_default_backend() == "auto"
  D.set_default_backend("lax")
  try:
    assert D.resolve_backend("isotonic", "kl", None, shape=(4, 9),
                             platform="cpu") == "lax"
  finally:
    D.set_default_backend("auto")
  assert D.resolve_backend("isotonic", "kl", None, shape=(4, 9),
                           platform="cpu") == "scan"


def test_shape_constrained_rules_never_match_shapeless_queries():
  """A plan cannot route an unknown-size problem to a size-gated backend
  — the old shape=None -> minimax bug class is unrepresentable."""
  gated = plan_mod.ExecutionPlan(name="gated", rules=(
      plan_mod.PlanRule("forward", "minimax", max_n=64),
      plan_mod.PlanRule("forward", "scan"),
  ))
  with plan_mod.use_plan(gated):
    assert D.resolve_backend("isotonic", "l2", None, shape=(4, 9),
                             platform="cpu") == "minimax"
    assert D.resolve_backend("isotonic", "l2", None, shape=None,
                             platform="cpu") == "scan"


def test_rule_matching_shape_buckets():
  r = plan_mod.PlanRule("forward", "minimax", min_n=8, max_n=64,
                        max_rows=100, max_elems=200_000)
  ok = dict(platform="cpu", dtype="*")
  assert r.matches("forward", "isotonic", "l2", shape=(4, 32), **ok)
  assert not r.matches("forward", "isotonic", "l2", shape=(4, 7), **ok)
  assert not r.matches("forward", "isotonic", "l2", shape=(4, 65), **ok)
  assert not r.matches("forward", "isotonic", "l2", shape=(101, 32), **ok)
  # rows * n^2 above the cap
  assert not r.matches("forward", "isotonic", "l2", shape=(100, 64), **ok)
  assert not r.matches("backward", "isotonic", "l2", shape=(4, 32), **ok)


# ---------------------------------------------------------------------------
# Serialization: round-trip, strictness, hashing.
# ---------------------------------------------------------------------------


def _sample_plan():
  return plan_mod.ExecutionPlan(
      name="sample",
      rules=(
          plan_mod.PlanRule("forward", "scan", op="isotonic",
                            regularization="l2", platform="cpu", max_n=6400,
                            evidence=("row/a", "row/b")),
          plan_mod.PlanRule("forward", "minimax", max_n=64,
                            max_elems=16_000_000),
          plan_mod.PlanRule("backward", "segscan"),
          plan_mod.PlanRule("projection", "fused", op="projection"),
      ),
      meta={"note": "test"})


def test_plan_round_trips_through_json(tmp_path):
  plan = _sample_plan()
  back = plan_mod.ExecutionPlan.from_json(plan.to_json())
  assert back == plan
  assert back.to_dict() == plan.to_dict()
  assert back.plan_hash() == plan.plan_hash()
  path = tmp_path / "plan.json"
  plan.save(str(path))
  assert plan_mod.load_plan(str(path)).to_dict() == plan.to_dict()


def test_plan_hash_ignores_meta_but_not_rules():
  plan = _sample_plan()
  import dataclasses
  remeta = dataclasses.replace(plan, meta={"unix_time": 123456})
  assert remeta.plan_hash() == plan.plan_hash()
  rerule = dataclasses.replace(
      plan, rules=plan.rules[:-1])
  assert rerule.plan_hash() != plan.plan_hash()


def test_plan_rejects_schema_version_mismatch():
  d = _sample_plan().to_dict()
  d["schema"] = "repro.plan/v0"
  with pytest.raises(ValueError, match="schema mismatch"):
    plan_mod.ExecutionPlan.from_dict(d)
  with pytest.raises(ValueError, match="schema mismatch"):
    plan_mod.ExecutionPlan.from_dict({"name": "no-schema", "rules": []})


def test_plan_rejects_unknown_fields():
  d = _sample_plan().to_dict()
  d["surprise"] = 1
  with pytest.raises(ValueError, match="unknown field.*surprise"):
    plan_mod.ExecutionPlan.from_dict(d)
  d = _sample_plan().to_dict()
  d["rules"][0]["cutoff"] = 64
  with pytest.raises(ValueError, match="unknown field.*cutoff"):
    plan_mod.ExecutionPlan.from_dict(d)


def test_plan_rejects_malformed_rules():
  with pytest.raises(ValueError, match="missing required field"):
    plan_mod.PlanRule.from_dict({"kind": "forward"})
  with pytest.raises(ValueError, match="kind must be one of"):
    plan_mod.PlanRule.from_dict({"kind": "sideways", "backend": "scan"})
  with pytest.raises(ValueError, match="evidence"):
    plan_mod.PlanRule.from_dict(
        {"kind": "forward", "backend": "scan", "evidence": [1, 2]})
  with pytest.raises(ValueError, match="not valid JSON"):
    plan_mod.ExecutionPlan.from_json("{nope")


def test_committed_default_plan_loads_and_is_hashable():
  plan = plan_mod.load_plan(plan_mod.DEFAULT_PLAN_PATH)
  assert plan.rules, "committed default plan must not be empty"
  assert len(plan.plan_hash()) == 12
  hash(plan)  # must be usable as a custom_vjp static argument
  for rule in plan.rules:
    assert rule.evidence, f"committed rule {rule} has no evidence"


# ---------------------------------------------------------------------------
# Plan-pinned execution: plans ride the custom VJPs as static args.
# ---------------------------------------------------------------------------


def test_plan_pins_backend_under_jit_and_grad():
  x = jnp.array(rng.normal(size=(3, 12)).astype(np.float32))
  pinned = plan_mod.ExecutionPlan(
      name="jit-pin", rules=(
          plan_mod.PlanRule("forward", "minimax"),
          plan_mod.PlanRule("backward", "scatter"),
          plan_mod.PlanRule("projection", "fused", op="projection"),
      ))

  @jax.jit
  def f(x):
    return soft_rank(x, plan=pinned).sum()

  metrics.reset()
  jax.grad(f)(x)
  c = metrics.counters("dispatch_calls")
  assert c.get("dispatch_calls{backend=minimax,op=isotonic,"
               "regularization=l2}", 0) >= 1
  cb = metrics.counters("dispatch_bwd_calls")
  assert cb.get("dispatch_bwd_calls{backend=scatter,op=projection,"
                "regularization=l2}", 0) >= 1


def test_plan_pinned_results_match_default_routing():
  x = jnp.array(rng.normal(size=(2, 3, 9)).astype(np.float32))
  pinned = plan_mod.ExecutionPlan(
      name="alt", rules=(
          plan_mod.PlanRule("forward", "lax"),
          plan_mod.PlanRule("backward", "scatter"),
      ))
  for fn in (lambda v, **kw: soft_sort(v, 0.5, "l2", **kw),
             lambda v, **kw: soft_rank(v, 0.5, "kl", **kw)):
    base = fn(x)
    alt = fn(x, plan=pinned)
    np.testing.assert_allclose(np.asarray(base), np.asarray(alt),
                               rtol=1e-5, atol=1e-5)
    gb = jax.grad(lambda v: fn(v).sum())(x)
    ga = jax.grad(lambda v: fn(v, plan=pinned).sum())(x)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(ga),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Acceptance: `--plan plan.json` (set_active_plan(load_plan(...))) and
# use_plan(plan) produce identical dispatch-counter traces.
# ---------------------------------------------------------------------------


def _workload():
  x = jnp.array(np.random.default_rng(5).normal(size=(4, 16))
                .astype(np.float32))
  w = jnp.arange(16.0, 0.0, -1.0)
  isotonic_l2(x)
  jax.grad(lambda v: isotonic_kl(v, w).sum())(x)
  soft_sort(x, 0.7, "l2")
  jax.grad(lambda v: soft_rank(v, 0.7, "kl").sum())(x)


def _dispatch_trace():
  return {k: v for k, v in metrics.counters("").items()
          if k.startswith(("dispatch", "projection", "plan_decide"))}


def test_plan_flag_and_use_plan_produce_identical_counter_traces(tmp_path):
  plan = plan_mod.ExecutionPlan(
      name="served", rules=(
          plan_mod.PlanRule("forward", "lax"),
          plan_mod.PlanRule("backward", "scatter"),
          plan_mod.PlanRule("projection", "fused", op="projection"),
      ))
  path = tmp_path / "plan.json"
  plan.save(str(path))

  # Path A: exactly what `launch/{train,serve}.py --plan plan.json` does.
  metrics.reset()
  plan_mod.set_active_plan(plan_mod.load_plan(str(path)))
  try:
    _workload()
  finally:
    plan_mod.set_active_plan(None)
  trace_flag = _dispatch_trace()

  # Path B: the context-manager API on the in-memory plan.
  metrics.reset()
  with plan_mod.use_plan(plan):
    _workload()
  trace_ctx = _dispatch_trace()

  assert trace_flag == trace_ctx
  assert any(k.startswith("plan_decide") for k in trace_flag)
  assert trace_flag.get("dispatch_calls{backend=lax,op=isotonic,"
                        "regularization=l2}", 0) >= 1


def test_plan_provenance_reports_governing_plan():
  prov = plan_mod.plan_provenance()
  assert prov["plan_source"] in ("default_plan", "builtin")
  pinned = plan_mod.ExecutionPlan(name="prov")
  with plan_mod.use_plan(pinned):
    prov = plan_mod.plan_provenance()
    assert prov == {"plan_name": "prov",
                    "plan_hash": pinned.plan_hash(),
                    "plan_source": "plan"}
  assert plan_mod.plan_provenance(pinned)["plan_source"] == "arg"

"""Dispatch layer: backend registry, auto resolution, cross-backend
equivalence (forward AND custom-VJP) on randomized batched inputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import soft_rank, soft_sort
from repro.core.isotonic import isotonic_kl, isotonic_l2
from repro.kernels import dispatch as D

rng = np.random.default_rng(7)

BATCHED_SHAPES = [(9,), (2, 3, 17)]


# ---------------------------------------------------------------------------
# Registry / resolution semantics.
# ---------------------------------------------------------------------------


def test_registry_contains_all_backends_for_both_regs():
  for reg in ("l2", "kl"):
    have = set(D.registered_backends("isotonic", reg))
    assert {"lax", "scan", "pallas", "minimax"} <= have


def test_backward_registry_contains_both_formulations():
  for reg in ("l2", "kl"):
    have = set(D.registered_backward_backends("isotonic", reg))
    assert have == {"segscan", "scatter"}


def test_auto_resolution_is_deterministic_per_platform():
  # Expected routes come from the committed autotuned default plan for
  # the platform it was measured on (cpu: lax for small-n few-row and
  # huge-n huge-batch cells, scan everywhere in between; see
  # src/repro/plan/default_plan.json) and from the built-in plan
  # everywhere else (tpu -> pallas; gpu is unmeasured -> builtin chain:
  # minimax under its small-n cap, scan beyond).
  for platform, shape, want in [
      ("tpu", (4, 9), "pallas"),
      ("tpu", (256, 4096), "pallas"),
      ("cpu", (4, 9), "lax"),
      ("cpu", (4, D.AUTO_MINIMAX_MAX_N + 1), "lax"),
      ("cpu", (1_000_000, 64), "scan"),
      ("cpu", (1, 10_000), "scan"),
      ("cpu", (32, 10_000), "scan"),
      ("cpu", (256, 10_000), "lax"),
      ("gpu", (4, 9), "minimax"),
      # huge flattened batch at small n: rows * n^2 memory rules minimax out
      ("gpu", (1_000_000, 64), "scan"),
      ("gpu", (4, 4096), "scan"),
  ]:
    got = [D.resolve_backend("isotonic", "l2", None, shape=shape,
                             platform=platform) for _ in range(3)]
    assert got == [want] * 3, (platform, shape, got)


def test_shapeless_auto_resolution_never_picks_minimax():
  """Regression: shape=None used to read as n=0, satisfying the small-n
  test and silently routing arbitrarily large problems to the O(n^2)
  backend."""
  for platform in ("cpu", "gpu"):
    assert D.resolve_backend("isotonic", "l2", None, shape=None,
                             platform=platform) == "scan"
  assert D.resolve_backend("isotonic", "kl", None, shape=None,
                           platform="tpu") == "pallas"


def test_explicit_backend_wins_over_default():
  with D.use_backend("minimax"):
    assert D.resolve_backend("isotonic", "l2", "lax", shape=(4, 9)) == "lax"
    assert D.resolve_backend("isotonic", "l2", None, shape=(4, 9)) == "minimax"


def test_env_var_override(monkeypatch):
  monkeypatch.setenv(D.ENV_VAR, "minimax")
  assert D.resolve_backend("isotonic", "l2", None, shape=(4, 500)) == "minimax"
  # explicit argument still wins over the environment
  assert D.resolve_backend("isotonic", "l2", "lax", shape=(4, 500)) == "lax"


def test_unknown_backend_raises():
  with pytest.raises(ValueError):
    D.resolve_backend("isotonic", "l2", "cuda", shape=(4, 9))
  with pytest.raises(ValueError):
    D.set_default_backend("nope")


def test_use_backend_restores_previous_default():
  before = D.get_default_backend()
  with pytest.raises(RuntimeError):
    with D.use_backend("lax"):
      raise RuntimeError("boom")
  assert D.get_default_backend() == before


# ---------------------------------------------------------------------------
# lax vs pallas (interpret mode on CPU) forward + VJP equivalence.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", BATCHED_SHAPES)
def test_isotonic_l2_lax_vs_pallas_fwd_and_vjp(shape):
  y = jnp.array(rng.normal(size=shape).astype(np.float32))
  u = jnp.array(rng.normal(size=shape).astype(np.float32))
  outs, grads = {}, {}
  for b in ("lax", "scan", "pallas", "minimax"):
    outs[b] = isotonic_l2(y, b)
    grads[b] = jax.grad(lambda t: jnp.sum(isotonic_l2(t, b) * u))(y)
  for b in ("scan", "pallas", "minimax"):
    np.testing.assert_allclose(outs[b], outs["lax"], atol=1e-5)
    np.testing.assert_allclose(grads[b], grads["lax"], atol=1e-5)


@pytest.mark.parametrize("shape", BATCHED_SHAPES)
def test_isotonic_kl_lax_vs_pallas_fwd_and_vjp(shape):
  s = jnp.array(np.sort(rng.normal(size=shape), -1)[..., ::-1].copy(),
                jnp.float32)
  w = jnp.array(np.sort(rng.normal(size=shape), -1)[..., ::-1].copy(),
                jnp.float32)
  u = jnp.array(rng.normal(size=shape).astype(np.float32))
  outs, gss, gws = {}, {}, {}
  for b in ("lax", "scan", "pallas", "minimax"):
    outs[b] = isotonic_kl(s, w, b)
    gss[b], gws[b] = jax.grad(
        lambda a, c: jnp.sum(isotonic_kl(a, c, b) * u), argnums=(0, 1))(s, w)
  for b in ("scan", "pallas", "minimax"):
    np.testing.assert_allclose(outs[b], outs["lax"], atol=5e-5)
    np.testing.assert_allclose(gss[b], gss["lax"], atol=5e-5)
    np.testing.assert_allclose(gws[b], gws["lax"], atol=5e-5)


@pytest.mark.parametrize("reg", ["l2", "kl"])
@pytest.mark.parametrize("shape", [(6, 13)])
def test_soft_ops_backends_agree_end_to_end(reg, shape):
  """soft_rank/soft_sort with explicit impl: fwd + VJP agree across
  backends through the whole sort -> PAV -> scatter pipeline."""
  theta = jnp.array(rng.normal(size=shape).astype(np.float32))

  def loss(t, impl, op):
    out = op(t, 0.4, reg, impl=impl)
    return jnp.sum(jnp.sin(out))

  # soft_rank exercises the same sort->PAV->scatter pipeline as soft_sort
  # (soft_sort differs only in which argument is batched, covered by
  # test_unbatched_w_fast_path_matches_batched_w).
  op = soft_rank
  f_lax = loss(theta, "lax", op)
  g_lax = jax.grad(lambda t: loss(t, "lax", op))(theta)
  for b in ("scan", "pallas", "minimax"):
    np.testing.assert_allclose(loss(theta, b, op), f_lax, atol=1e-5)
    np.testing.assert_allclose(
        jax.grad(lambda t: loss(t, b, op))(theta), g_lax, atol=1e-5)


def test_unbatched_w_fast_path_matches_batched_w():
  """projection with w of shape (n,) must equal explicitly-broadcast w."""
  from repro.core.projection import projection_permutahedron
  z = jnp.array(rng.normal(size=(4, 8)).astype(np.float32))
  w1 = jnp.array(rng.normal(size=(8,)).astype(np.float32))
  wb = jnp.broadcast_to(w1, z.shape)
  for reg in ("l2", "kl"):
    np.testing.assert_allclose(
        projection_permutahedron(z, w1, reg),
        projection_permutahedron(z, wb, reg), atol=1e-6)
    # gradient through unbatched w accumulates over the batch
    g1 = jax.grad(lambda w: jnp.sum(
        projection_permutahedron(z, w, reg) ** 2))(w1)
    gb = jax.grad(lambda w: jnp.sum(
        projection_permutahedron(z, w, reg) ** 2))(wb)
    np.testing.assert_allclose(g1, gb.sum(0), atol=1e-4)


def test_default_path_is_single_dispatch_no_vmap():
  """The default path lowers to ONE isotonic solve over the flattened
  batch: count custom_vjp calls in the jaxpr of a batched soft_rank."""
  theta = jnp.array(rng.normal(size=(4, 3, 9)).astype(np.float32))
  jaxpr = jax.make_jaxpr(lambda t: soft_rank(t, 0.5))(theta)
  text = str(jaxpr)
  assert text.count("custom_vjp_call") == 1, text


def test_vjp_matches_finite_difference_batched_all_backends():
  y = jnp.array(rng.normal(size=(2, 5)).astype(np.float32))
  u = jnp.array(rng.normal(size=(2, 5)).astype(np.float32))
  eps = 1e-3
  # pallas omitted: its VJP is literally the same backward function (only
  # forwards differ), and grad equality to lax is asserted above.
  for b in ("lax", "scan", "minimax"):
    f = lambda t: jnp.sum(isotonic_l2(t, b) * u)
    g = jax.grad(f)(y)
    fd = np.zeros((2, 5), np.float32)
    for i in range(2):
      for j in range(5):
        fd[i, j] = (f(y.at[i, j].add(eps))
                    - f(y.at[i, j].add(-eps))) / (2 * eps)
    np.testing.assert_allclose(g, fd, atol=2e-2)


# ---------------------------------------------------------------------------
# Backward (VJP) dispatch: segscan vs scatter formulations.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", BATCHED_SHAPES + [(5, 64), (3, 100)])
def test_l2_backward_backends_agree(shape):
  """New default (segscan) vs reference (scatter): max abs diff <= 1e-5."""
  y = jnp.array(rng.normal(size=shape).astype(np.float32))
  u = jnp.array(rng.normal(size=shape).astype(np.float32))
  f = lambda t: jnp.sum(isotonic_l2(t) * u)
  with D.use_backward("segscan"):
    g_new = jax.grad(f)(y)
  with D.use_backward("scatter"):
    g_old = jax.grad(f)(y)
  assert float(jnp.max(jnp.abs(g_new - g_old))) <= 1e-5


@pytest.mark.parametrize("shape", BATCHED_SHAPES + [(5, 64)])
def test_kl_backward_backends_agree(shape):
  s = jnp.array(rng.normal(size=shape).astype(np.float32))
  w = jnp.array(rng.normal(size=shape).astype(np.float32))
  u = jnp.array(rng.normal(size=shape).astype(np.float32))
  f = lambda a, c: jnp.sum(isotonic_kl(a, c) * u)
  grads = {}
  for b in ("segscan", "scatter"):
    with D.use_backward(b):
      grads[b] = jax.grad(f, argnums=(0, 1))(s, w)
  for new, old in zip(grads["segscan"], grads["scatter"]):
    assert float(jnp.max(jnp.abs(new - old))) <= 1e-5


def test_backward_resolution_precedence(monkeypatch):
  # default: auto -> segscan
  assert D.resolve_backward("isotonic", "l2", None) == "segscan"
  # env overrides default
  monkeypatch.setenv(D.BWD_ENV_VAR, "scatter")
  assert D.resolve_backward("isotonic", "l2", None) == "scatter"
  # explicit argument wins over env
  assert D.resolve_backward("isotonic", "l2", "segscan") == "segscan"
  monkeypatch.delenv(D.BWD_ENV_VAR)
  with pytest.raises(ValueError):
    D.resolve_backward("isotonic", "l2", "cuda")
  with pytest.raises(ValueError):
    D.set_default_backward("nope")


def test_use_backward_restores_previous_default():
  before = D.get_default_backward()
  with pytest.raises(RuntimeError):
    with D.use_backward("scatter"):
      raise RuntimeError("boom")
  assert D.get_default_backward() == before


# ---------------------------------------------------------------------------
# Trace-key cache stays bounded.
# ---------------------------------------------------------------------------


def test_trace_key_cache_is_capped_and_counts_evictions(monkeypatch):
  from repro.obs import metrics
  monkeypatch.setattr(D, "TRACE_KEY_CAP", 3)
  metrics.set_enabled(True)
  try:
    metrics.reset()
    for n in range(2, 10):  # 8 distinct shapes through a cap of 3
      D.dispatch("isotonic", "l2", "lax", jnp.zeros((1, n), jnp.float32))
    assert len(D._SEEN_TRACE_KEYS) <= 3
    evicts = sum(metrics.counters("dispatch_trace_cache_evict").values())
    assert evicts == 5
    # repeats hit, never evict
    D.dispatch("isotonic", "l2", "lax", jnp.zeros((1, 9), jnp.float32))
    assert sum(metrics.counters("dispatch_trace_cache_hit").values()) == 1
  finally:
    metrics.set_enabled(None)
    metrics.reset()


# ---------------------------------------------------------------------------
# Uniform promote-compute-demote dtype contract (bf16/f16) across backends.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("half", [jnp.bfloat16, jnp.float16])
def test_half_dtype_contract_uniform_across_backends(half):
  """Every backend must accept half inputs (dispatch promotes to f32,
  computes, demotes) and agree with every other backend on the result —
  no backend carries its own casting wrapper anymore."""
  x32 = jnp.array(rng.normal(size=(3, 21)).astype(np.float32))
  xh = x32.astype(half)
  w32 = jnp.array(np.sort(rng.normal(size=(21,)))[::-1].copy()
                  .astype(np.float32))
  wh = jnp.broadcast_to(w32.astype(half), xh.shape)

  outs_l2, outs_kl = {}, {}
  for backend in ("lax", "scan", "minimax"):
    o2 = D.dispatch("isotonic", "l2", backend, xh)
    ok = D.dispatch("isotonic", "kl", backend, xh, wh)
    assert o2.dtype == half and ok.dtype == half, backend
    outs_l2[backend], outs_kl[backend] = o2, ok
  for backend in ("scan", "minimax"):
    np.testing.assert_allclose(
        np.asarray(outs_l2[backend], np.float32),
        np.asarray(outs_l2["lax"], np.float32), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(outs_kl[backend], np.float32),
        np.asarray(outs_kl["lax"], np.float32), rtol=2e-2, atol=2e-2)


def test_bf16_matches_f32_reference_through_operators():
  """bf16 in -> bf16 out for the public operators, numerically tracking
  the f32 result to bf16 precision, including gradients."""
  x32 = jnp.array(rng.normal(size=(2, 17)).astype(np.float32))
  xb = x32.astype(jnp.bfloat16)
  for fn in (lambda v: soft_sort(v, 0.5, "l2"),
             lambda v: soft_rank(v, 0.5, "kl")):
    out32 = fn(x32)
    outb = fn(xb)
    assert outb.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(outb, np.float32),
                               np.asarray(out32), rtol=4e-2, atol=4e-2)
    g32 = jax.grad(lambda v: (fn(v) ** 2).sum())(x32)
    gb = jax.grad(lambda v: (fn(v) ** 2).sum())(xb)
    assert gb.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(gb, np.float32),
                               np.asarray(g32), rtol=1e-1, atol=1e-1)


def test_backward_dispatch_promotes_half_grads():
  """dispatch_backward applies the same contract: half cotangents are
  solved in f32 and demoted, int/bool structure args pass through."""
  xb = jnp.array(rng.normal(size=(2, 9)).astype(np.float32)
                 ).astype(jnp.bfloat16)
  g = jax.grad(lambda v: isotonic_l2(v).astype(jnp.float32).sum())(xb)
  assert g.dtype == jnp.bfloat16
  assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))

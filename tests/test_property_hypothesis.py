"""Property-based tests (hypothesis) for the paper's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import soft_rank, soft_sort, soft_topk_mask

SETTINGS = dict(max_examples=40, deadline=None)

floats = st.floats(min_value=-50, max_value=50, allow_nan=False,
                   allow_infinity=False)
vectors = st.lists(floats, min_size=1, max_size=24)
eps_strat = st.floats(min_value=1e-3, max_value=1e3, allow_nan=False)


def _arr(v):
  return jnp.array(np.asarray(v, np.float32))


@given(vectors, eps_strat)
@settings(**SETTINGS)
def test_sort_output_monotone(v, eps):
  s = soft_sort(_arr(v), eps)
  assert bool(jnp.all(s[:-1] >= s[1:] - 1e-4 * (1 + jnp.abs(s[:-1]))))


@given(vectors, eps_strat)
@settings(**SETTINGS)
def test_sort_sum_conserved(v, eps):
  x = _arr(v)
  np.testing.assert_allclose(
      float(jnp.sum(soft_sort(x, eps))), float(jnp.sum(x)),
      rtol=1e-3, atol=1e-3)


@given(vectors, eps_strat)
@settings(**SETTINGS)
def test_rank_in_permutahedron(v, eps):
  """Majorization check: soft ranks lie in P((n,...,1)).

  y in P(w) iff sum(y) == sum(w) and for all k, the sum of the k largest
  entries of y is <= sum of k largest of w.
  """
  x = _arr(v)
  n = x.shape[0]
  r = np.sort(np.asarray(soft_rank(x, eps)))[::-1]
  w = np.arange(n, 0, -1, dtype=np.float64)
  np.testing.assert_allclose(r.sum(), w.sum(), rtol=1e-3, atol=1e-3)
  tol = 1e-3 * n * n
  assert np.all(np.cumsum(r) <= np.cumsum(w) + tol)


@given(vectors, eps_strat)
@settings(**SETTINGS)
def test_rank_translation_invariance(v, eps):
  x = _arr(v)
  r1 = soft_rank(x, eps)
  r2 = soft_rank(x + 7.5, eps)
  np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                             rtol=1e-3, atol=1e-3)


@given(vectors, eps_strat)
@settings(**SETTINGS)
def test_rank_permutation_equivariance(v, eps):
  x = np.asarray(v, np.float32)
  perm = np.random.default_rng(0).permutation(len(x))
  r = np.asarray(soft_rank(_arr(x), eps))
  rp = np.asarray(soft_rank(_arr(x[perm]), eps))
  # ties can resolve differently across permutations; only check when the
  # input has no near-ties
  sx = np.sort(x)
  if len(x) > 1 and np.min(np.diff(sx)) < 1e-3:
    return
  np.testing.assert_allclose(rp, r[perm], rtol=1e-3, atol=2e-3)


@given(vectors, eps_strat)
@settings(**SETTINGS)
def test_scaling_relation(v, eps):
  """r_{eps,Q}(c * theta) == r_{eps/c,Q}(theta) for c > 0."""
  x = _arr(v)
  c = 3.0
  r1 = soft_rank(c * x, eps)
  r2 = soft_rank(x, eps / c)
  np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                             rtol=1e-3, atol=2e-3)


@given(vectors, st.integers(min_value=1, max_value=5), eps_strat)
@settings(**SETTINGS)
def test_topk_mask_bounds_and_sum(v, k, eps):
  x = _arr(v)
  n = x.shape[0]
  k = min(k, n)
  m = np.asarray(soft_topk_mask(x, k, eps))
  assert np.all(m >= -1e-4) and np.all(m <= 1 + 1e-4)
  np.testing.assert_allclose(m.sum(), k, rtol=1e-3, atol=1e-3)


@given(vectors)
@settings(**SETTINGS)
def test_gradients_finite(v):
  x = _arr(v)
  g = jax.grad(lambda t: jnp.sum(jnp.sin(soft_rank(t, 0.3))))(x)
  assert bool(jnp.all(jnp.isfinite(g)))
  g2 = jax.grad(lambda t: jnp.sum(jnp.sin(soft_sort(t, 0.3, "kl"))))(x)
  assert bool(jnp.all(jnp.isfinite(g2)))


# ---------------------------------------------------------------------------
# "scan" (divide-and-conquer PAV) backend vs the "lax" reference.
# ---------------------------------------------------------------------------

# Sizes straddle power-of-two boundaries on purpose: the scan backend pads
# rows to the next power of two with sentinel blocks, and an off-by-one
# there only shows up at non-power-of-two n.
scan_ns = st.integers(min_value=1, max_value=67)
rows_strat = st.integers(min_value=1, max_value=4)


def _row_batch(data, rows, n, kind):
  rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1),
                                        label="seed"))
  x = rng.normal(scale=10.0, size=(rows, n))
  if kind == "all_equal":
    x = np.broadcast_to(x[:, :1], (rows, n)).copy()
  elif kind == "descending":
    x = -np.sort(x, axis=-1)
  elif kind == "ascending":  # worst case: everything pools into one block
    x = np.sort(x, axis=-1)
  return x


@given(st.data(), scan_ns, rows_strat,
       st.sampled_from(["random", "all_equal", "descending", "ascending"]),
       st.sampled_from([np.float32, np.float64]))
@settings(**SETTINGS)
def test_scan_backend_matches_lax_l2(data, n, rows, kind, dtype):
  from repro.core.isotonic import isotonic_l2
  x = _row_batch(data, rows, n, kind).astype(dtype)
  with jax.experimental.enable_x64(dtype == np.float64):
    a = np.asarray(isotonic_l2(jnp.asarray(x), "scan"))
    b = np.asarray(isotonic_l2(jnp.asarray(x), "lax"))
  np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@given(st.data(), scan_ns, rows_strat,
       st.sampled_from(["random", "all_equal", "descending", "ascending"]),
       st.sampled_from([np.float32, np.float64]))
@settings(**SETTINGS)
def test_scan_backend_matches_lax_kl(data, n, rows, kind, dtype):
  from repro.core.isotonic import isotonic_kl
  s = _row_batch(data, rows, n, kind).astype(dtype)
  w = _row_batch(data, rows, n, "random").astype(dtype)
  with jax.experimental.enable_x64(dtype == np.float64):
    a = np.asarray(isotonic_kl(jnp.asarray(s), jnp.asarray(w), "scan"))
    b = np.asarray(isotonic_kl(jnp.asarray(s), jnp.asarray(w), "lax"))
  np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@given(st.data(), scan_ns, rows_strat)
@settings(**SETTINGS)
def test_scan_backend_vjp_matches_lax(data, n, rows):
  from repro.core.isotonic import isotonic_l2
  x = jnp.asarray(_row_batch(data, rows, n, "random").astype(np.float32))
  u = jnp.asarray(_row_batch(data, rows, n, "random").astype(np.float32))
  g_scan = jax.grad(lambda t: jnp.sum(isotonic_l2(t, "scan") * u))(x)
  g_lax = jax.grad(lambda t: jnp.sum(isotonic_l2(t, "lax") * u))(x)
  np.testing.assert_allclose(np.asarray(g_scan), np.asarray(g_lax),
                             rtol=1e-5, atol=1e-4)

"""Substrate tests: optimizer math, schedules, compression, data, checkpoints."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.optim.compression import ef_int8_roundtrip, init_residual
from repro.optim.schedule import cosine_with_warmup


def test_adamw_matches_numpy_reference():
  cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.95, eps=1e-8,
                          weight_decay=0.0, clip_norm=1e9)
  p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]])}
  g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]])}
  st = adamw.init(cfg, p)
  new_p, st, _ = adamw.update(cfg, g, st, p)
  # numpy reference (step 1)
  gn = np.array(g["w"])
  m = 0.1 * gn
  v = 0.05 * gn * gn
  mhat = m / (1 - 0.9)
  vhat = v / (1 - 0.95)
  want = np.array(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
  np.testing.assert_allclose(new_p["w"], want, rtol=1e-5)


def test_adamw_clipping():
  cfg = adamw.AdamWConfig(lr=0.0, clip_norm=1.0)
  p = {"w": jnp.ones((4,))}
  g = {"w": jnp.full((4,), 100.0)}
  st = adamw.init(cfg, p)
  _, _, metrics = adamw.update(cfg, g, st, p)
  assert float(metrics["grad_norm"]) > 100
  assert float(metrics["clip_scale"]) < 0.01


def test_quantile_clip_adapts():
  cfg = adamw.AdamWConfig(lr=0.01, quantile_clip=0.5, quantile_window=4)
  p = {"w": jnp.ones((4,))}
  st = adamw.init(cfg, p)
  for i in range(6):
    g = {"w": jnp.full((4,), 0.1 * (i + 1))}
    p, st, metrics = adamw.update(cfg, g, st, p)
  # clip threshold should now reflect the observed norms, not the default
  assert 0.05 < float(metrics["clip_at"]) < 2.5


def test_schedule_shape():
  assert float(cosine_with_warmup(0, warmup=10, total=100)) == 0.0
  assert abs(float(cosine_with_warmup(10, warmup=10, total=100)) - 1) < 1e-6
  assert float(cosine_with_warmup(100, warmup=10, total=100)) < 0.2


def test_error_feedback_compensates():
  """EF property: accumulated decoded gradient tracks accumulated true
  gradient (residual stays bounded)."""
  rng = np.random.default_rng(0)
  g_true = {"w": jnp.array(rng.normal(size=(64,)).astype(np.float32))}
  res = init_residual(g_true)
  total_dec = np.zeros(64)
  for step in range(20):
    dec, res = ef_int8_roundtrip(g_true, res)
    total_dec += np.asarray(dec["w"])
  # average decoded ~= true gradient
  np.testing.assert_allclose(total_dec / 20, np.asarray(g_true["w"]),
                             atol=1e-2)


def test_pipeline_determinism_and_resume():
  cfg = DataConfig(vocab_size=1000, global_batch=4, seq_len=16, seed=7)
  p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
  for step in (0, 5, 1000):
    b1, b2 = p1.batch_at(step), p2.batch_at(step)
    for k in b1:
      np.testing.assert_array_equal(b1[k], b2[k])
  assert not np.array_equal(p1.batch_at(1)["tokens"],
                            p1.batch_at(2)["tokens"])


def test_pipeline_host_sharding_partitions():
  kw = dict(vocab_size=100, global_batch=8, seq_len=4, seed=1, num_hosts=2)
  a = TokenPipeline(DataConfig(host_id=0, **kw)).batch_at(3)
  b = TokenPipeline(DataConfig(host_id=1, **kw)).batch_at(3)
  assert a["tokens"].shape == (4, 4)
  assert not np.array_equal(a["tokens"], b["tokens"])


def test_checkpoint_roundtrip_and_gc():
  tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
          "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
  with tempfile.TemporaryDirectory() as d:
    for s in (1, 2, 3, 4):
      ckpt.save(d, s, tree, {"step": s}, keep=2)
    assert ckpt.all_steps(d) == [3, 4]
    back, meta = ckpt.restore(d, tree)
    assert meta["step"] == 4
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
      np.testing.assert_allclose(np.asarray(x, np.float32),
                                 np.asarray(y, np.float32))


def test_checkpoint_atomicity_tmp_never_visible():
  tree = {"a": jnp.zeros((128, 128))}
  with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 7, tree)
    names = os.listdir(d)
    assert all(not n.startswith("tmp.") for n in names)


def test_async_checkpointer():
  tree = {"a": jnp.arange(10)}
  with tempfile.TemporaryDirectory() as d:
    ac = ckpt.AsyncCheckpointer(d, keep=3)
    for s in range(5):
      ac.save(s, jax.tree.map(lambda x: x + s, tree))
    ac.wait()
    assert ckpt.latest_step(d) == 4
    back, _ = ckpt.restore(d, tree)
    np.testing.assert_array_equal(back["a"], np.arange(10) + 4)

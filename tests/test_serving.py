"""`repro.serving`: bucket policy, AOT executable cache, admission
control (typed shedding), micro-batch engine end-to-end (padded results
bitwise vs the unpadded operators per backend), plan-derived warmup
(zero post-warmup misses), and the jit-stable dispatch entries."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import plan as plan_mod
from repro.core import soft_rank
from repro.core.losses import soft_lts_loss
from repro.kernels import dispatch as D
from repro.obs import metrics
from repro.serving import (
    AOTExecutableCache,
    AdmissionQueue,
    BucketPolicy,
    EngineConfig,
    Request,
    ServingEngine,
    STATUS_ERROR,
    STATUS_OK,
    STATUS_SHED_DEADLINE,
    STATUS_SHED_QUEUE_FULL,
    SERVING_OPS,
    synthetic_stream,
)
from repro.serving.ops import bound_op

rng = np.random.default_rng(3)


@pytest.fixture(autouse=True)
def clean_metrics():
  metrics.set_enabled(True)
  metrics.reset()
  yield
  metrics.set_enabled(None)
  metrics.reset()


class FakeClock:
  def __init__(self, t=100.0):
    self.t = t

  def __call__(self):
    return self.t


def _req(n=5, op="soft_rank/l2/desc", **kw):
  return Request(op=op, values=rng.standard_normal(n).astype(np.float32),
                 eps=0.5, **kw)


# ---------------------------------------------------------------------------
# Bucket policy.
# ---------------------------------------------------------------------------


def test_bucket_policy_pow2_ladder_and_lookup():
  p = BucketPolicy.pow2(min_n=64, max_n=4096, max_batch=8)
  assert p.sizes == (64, 128, 256, 512, 1024, 2048, 4096)
  assert p.row_sizes == (1, 2, 4, 8)
  assert p.bucket_for(1) == 64
  assert p.bucket_for(64) == 64
  assert p.bucket_for(65) == 128
  assert p.bucket_for(4096) == 4096
  assert p.rows_for(3) == 4
  with pytest.raises(ValueError, match="exceeds the largest bucket"):
    p.bucket_for(4097)
  with pytest.raises(ValueError, match=">= 1"):
    p.bucket_for(0)


def test_bucket_policy_from_plan_splices_breakpoints():
  plan = plan_mod.ExecutionPlan(name="edges", rules=(
      plan_mod.PlanRule("forward", "minimax", max_n=100,
                        max_elems=10**6),
      plan_mod.PlanRule("forward", "scan", min_n=3000),
  ))
  p = BucketPolicy.from_plan(plan, min_n=64, max_n=4096, max_batch=4)
  # 100 (a max_n edge) and 2999 (min_n - 1) join the pow2 ladder, so no
  # bucket pads a request across a backend cutoff.
  assert 100 in p.sizes and 2999 in p.sizes
  assert p.bucket_for(70) == 100      # would have been 128 without the plan
  assert p.bucket_for(101) == 128
  # Builtin-plan edges (e.g. the minimax small-n cutoff at 64) are also
  # representable: the chain is consulted when plan=None.
  assert BucketPolicy.from_plan(None, min_n=8, max_n=128,
                                max_batch=2).bucket_for(8) <= 64


def test_shape_breakpoints_and_resolve_grid():
  plan = plan_mod.ExecutionPlan(name="edges", rules=(
      plan_mod.PlanRule("forward", "minimax", max_n=100, max_elems=10**6),
      plan_mod.PlanRule("forward", "scan"),
  ))
  edges = plan_mod.shape_breakpoints(plan)
  assert 100 in edges
  grid = plan_mod.resolve_grid(
      "forward", ["isotonic"], ["l2"], [(4, 32), (4, 4096)],
      platform="cpu", plan=plan)
  assert [g["backend"] for g in grid] == ["minimax", "scan"]
  assert all(g["plan"] == "edges" and g["source"] == "plan" for g in grid)
  # Enumeration must not pollute dispatch-decision counters.
  assert metrics.counters("plan_decide") == {}


# ---------------------------------------------------------------------------
# AOT executable cache.
# ---------------------------------------------------------------------------


def test_aot_cache_hit_miss_warm_evict_counters():
  cache = AOTExecutableCache(capacity=2)
  builds = []

  def builder(tag):
    def build():
      builds.append(tag)
      return ("exe", tag)
    return build

  assert cache.warm("a", builder("a")) is True
  assert cache.warm("a", builder("a")) is False     # already resident
  assert cache.get("a", builder("a")) == ("exe", "a")
  assert cache.get("b", builder("b")) == ("exe", "b")   # miss, compile
  assert cache.get("c", builder("c")) == ("exe", "c")   # miss, evicts "a"
  assert len(cache) == 2 and "a" not in cache
  assert builds == ["a", "b", "c"]
  c = metrics.counters()
  assert c["aot_cache_warm"] == 1
  assert c["aot_cache_hit"] == 1
  assert c["aot_cache_miss"] == 2
  assert c["aot_cache_evict"] == 1


def test_aot_cache_lru_order():
  cache = AOTExecutableCache(capacity=2)
  cache.warm("a", lambda: 1)
  cache.warm("b", lambda: 2)
  cache.get("a", lambda: 1)        # refresh "a"
  cache.get("c", lambda: 3)        # evicts "b", the least recently used
  assert "a" in cache and "b" not in cache and "c" in cache


# ---------------------------------------------------------------------------
# Admission queue.
# ---------------------------------------------------------------------------


def test_queue_reject_on_full_and_fifo_groups():
  fc = FakeClock()
  q = AdmissionQueue(capacity=3, clock=fc)
  a, b, c, d = _req(3), _req(4), _req(3, op="soft_sort/l2/desc"), _req(5)
  for r in (a, b, c):
    r.bucket_n = 64
    assert q.try_push(r)
  d.bucket_n = 64
  assert not q.try_push(d)                  # bounded: reject, don't grow
  assert q.head_group_size() == 2           # a and b share (op, bucket)
  got = q.pop_group(max_batch=8)
  assert [r.request_id for r in got] == [a.request_id, b.request_id]
  assert len(q) == 1                        # c kept its place


def test_queue_deadline_expiry():
  fc = FakeClock()
  q = AdmissionQueue(capacity=8, clock=fc)
  r1, r2 = _req(3), _req(3)
  r1.submitted_at = fc.t
  r1.deadline_at = fc.t + 0.005
  r2.submitted_at = fc.t                    # no deadline: never expires
  q.try_push(r1)
  q.try_push(r2)
  assert q.expire() == []
  fc.t += 0.006
  expired = q.expire()
  assert [r.request_id for r in expired] == [r1.request_id]
  assert len(q) == 1


# ---------------------------------------------------------------------------
# Engine: admission statuses are typed results, never exceptions.
# ---------------------------------------------------------------------------


def test_engine_shed_queue_full_is_typed():
  eng = ServingEngine(EngineConfig(ops=("soft_rank/l2/desc",), min_bucket=8,
                                   max_bucket=16, max_batch=2,
                                   queue_capacity=2), clock=FakeClock())
  handles = [eng.submit(_req(5)) for _ in range(3)]
  assert not handles[0].done() and not handles[1].done()
  res = handles[2].result(timeout=0)
  assert res.status == STATUS_SHED_QUEUE_FULL and not res.ok
  assert metrics.counter_value("serving_shed", reason="queue_full") == 1
  assert metrics.counter_value("serving_admit", op="soft_rank") == 2


def test_engine_shed_deadline_in_queue():
  fc = FakeClock()
  eng = ServingEngine(EngineConfig(ops=("soft_rank/l2/desc",), min_bucket=8,
                                   max_bucket=16, max_batch=4,
                                   max_wait_ms=1000.0), clock=fc)
  h = eng.submit(_req(5, deadline_ms=5.0))
  fc.t += 0.006
  stepped = eng.step()
  assert [r.status for r in stepped] == [STATUS_SHED_DEADLINE]
  res = h.result(timeout=0)
  assert res.status == STATUS_SHED_DEADLINE
  assert res.latency_us == pytest.approx(6000.0, rel=0.01)
  assert metrics.counter_value("serving_shed", reason="deadline") == 1
  assert len(eng.queue) == 0


def test_engine_invalid_requests_are_typed_errors():
  eng = ServingEngine(EngineConfig(ops=("soft_rank/l2/desc",), min_bucket=8,
                                   max_bucket=16, max_batch=2),
                      clock=FakeClock())
  bad_op = eng.submit(_req(5, op="nope/l2"))
  assert bad_op.result(0).status == STATUS_ERROR
  assert "unknown serving op" in bad_op.result(0).detail
  too_big = eng.submit(_req(999))
  assert too_big.result(0).status == STATUS_ERROR
  assert "exceeds the largest bucket" in too_big.result(0).detail


def test_engine_default_deadline_applies():
  fc = FakeClock()
  eng = ServingEngine(EngineConfig(ops=("soft_rank/l2/desc",), min_bucket=8,
                                   max_bucket=16, max_batch=4,
                                   default_deadline_ms=2.0), clock=fc)
  h = eng.submit(_req(5))
  assert h.deadline_at == pytest.approx(fc.t + 0.002)
  fc.t += 0.003
  eng.step()
  assert h.result(0).status == STATUS_SHED_DEADLINE


# ---------------------------------------------------------------------------
# Engine: batching policy (fake clock; first exec lazily compiles).
# ---------------------------------------------------------------------------


def test_engine_max_wait_and_max_batch_policy():
  fc = FakeClock()
  eng = ServingEngine(EngineConfig(ops=("soft_rank/l2/desc",), min_bucket=8,
                                   max_bucket=8, max_batch=2, impl="lax",
                                   max_wait_ms=10.0), clock=fc)
  h1 = eng.submit(_req(5))
  assert eng.step() == []                  # under-full and not yet due
  assert len(eng.queue) == 1
  fc.t += 0.02                             # past max-wait: due
  res = eng.step()
  assert [r.status for r in res] == [STATUS_OK]
  assert h1.result(0).ok
  assert metrics.counter_value("aot_cache_miss") == 1   # lazy compile
  # A full group launches immediately, no clock advance needed — but a
  # 2-row batch is a different (rows, bucket) cell: second lazy compile.
  h2, h3 = eng.submit(_req(6)), eng.submit(_req(7))
  res = eng.step()
  assert len(res) == 2 and h2.result(0).ok and h3.result(0).ok
  assert metrics.counter_value("aot_cache_miss") == 2
  # The same cell again is a cache hit.
  h4, h5 = eng.submit(_req(3)), eng.submit(_req(8))
  eng.step()
  assert h4.result(0).ok and h5.result(0).ok
  assert metrics.counter_value("aot_cache_hit") == 1
  occ = metrics.histograms("serving_batch_occupancy")
  assert sum(h["count"] for h in occ.values()) == 3     # three batches


# ---------------------------------------------------------------------------
# Engine end-to-end: warmup -> mixed-n stream -> exact results, no misses.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def warm_engine():
  cfg = EngineConfig(ops=("soft_rank/l2/desc", "lts/l2"), min_bucket=8,
                     max_bucket=16, max_batch=2, max_wait_ms=0.0,
                     impl="lax", use_plan_buckets=False)
  eng = ServingEngine(cfg)
  compiled = eng.warmup()
  assert compiled == 2 * 2 * 2             # ops x n-buckets x row-buckets
  return eng


def test_engine_end_to_end_bitwise_and_zero_miss(warm_engine):
  eng = warm_engine
  reqs = [_req(n) for n in (3, 8, 5, 11, 16, 2, 7)]
  results = eng.serve(reqs)
  assert all(r.ok for r in results)
  for req, res in zip(reqs, results):
    ref = np.asarray(soft_rank(jnp.asarray(req.values)[None], req.eps,
                               "l2", "DESCENDING", impl="lax"))[0]
    # The padding contract: sliced-back engine output is bitwise equal
    # to the unpadded operator on the same backend.
    np.testing.assert_array_equal(res.value, ref)
    assert res.n == req.n and res.bucket_n >= req.n
  assert metrics.counter_value("aot_cache_miss") == 0
  assert metrics.counters("aot_cache_hit")      # served from warm cache
  lat = metrics.histograms("serving_latency_us")
  assert sum(h["count"] for h in lat.values()) == len(reqs)


def test_engine_scalar_op_matches_unpadded_loss(warm_engine):
  vals = rng.standard_normal(11).astype(np.float32)
  h = warm_engine.submit(Request(op="lts/l2", values=vals, eps=0.7,
                                 extras={"trim": 3}))
  warm_engine.drain()
  res = h.result(timeout=0)
  assert res.ok
  pin_lax = plan_mod.ExecutionPlan(name="pin-lax", rules=(
      plan_mod.PlanRule("forward", "lax"),))
  ref = float(soft_lts_loss(jnp.asarray(vals), 3, 0.7, "l2", plan=pin_lax))
  assert res.value == pytest.approx(ref, rel=1e-5)


def test_engine_background_thread_smoke(warm_engine):
  warm_engine.start()
  try:
    handles = [warm_engine.submit(_req(n)) for n in (4, 9, 13)]
    results = [h.result(timeout=30.0) for h in handles]
  finally:
    warm_engine.stop()
  assert all(r.ok for r in results)


def test_synthetic_stream_is_deterministic_and_in_range():
  a = synthetic_stream(20, seed=5, n_min=8, n_max=64)
  b = synthetic_stream(20, seed=5, n_min=8, n_max=64)
  assert [r.n for r in a] == [r.n for r in b]
  assert all(8 <= r.n <= 64 for r in a)
  assert all(r.op in SERVING_OPS for r in a)
  np.testing.assert_array_equal(a[0].values, b[0].values)


# ---------------------------------------------------------------------------
# Jit-stable entries.
# ---------------------------------------------------------------------------


def test_stable_entry_identity_and_dispatch():
  f = D.stable_entry("isotonic", "l2", "lax")
  assert f is D.stable_entry("isotonic", "l2", "lax")
  assert f is not D.stable_entry("isotonic", "l2", "scan")
  assert D.stable_entry("isotonic", "l2", "segscan", kind="backward") is \
      D.stable_entry("isotonic", "l2", "segscan", kind="backward")
  with pytest.raises(ValueError, match="kind"):
    D.stable_entry("isotonic", "l2", "lax", kind="projection")
  y = jnp.asarray(rng.standard_normal((2, 9)).astype(np.float32))
  np.testing.assert_array_equal(
      np.asarray(jax.jit(f)(y)),
      np.asarray(D.dispatch("isotonic", "l2", "lax", y)))


def test_stable_entry_distinguishes_plans():
  plan = plan_mod.ExecutionPlan(name="p", rules=(
      plan_mod.PlanRule("forward", "lax"),))
  f_plain = D.stable_entry("isotonic", "l2")
  f_plan = D.stable_entry("isotonic", "l2", plan=plan)
  assert f_plain is not f_plan
  assert f_plan is D.stable_entry("isotonic", "l2", plan=plan)


def test_bound_op_identity():
  assert bound_op("soft_rank/l2/desc", "lax", None) is \
      bound_op("soft_rank/l2/desc", "lax", None)
  assert bound_op("soft_rank/l2/desc", "lax", None) is not \
      bound_op("soft_rank/l2/desc", "scan", None)

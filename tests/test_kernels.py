"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import soft_rank, soft_topk_mask
from repro.core.isotonic import use_impl
from repro.kernels.ops import pav_kl, pav_l2, soft_topk_gates
from repro.kernels.ref import pav_kl_ref, pav_l2_ref, soft_topk_gates_ref
from repro.kernels.soft_topk import _bitonic

rng = np.random.default_rng(3)

# Interpret-mode pallas_call compiles slowly per shape on CPU: keep a small
# representative sweep in the fast tier, push the large shapes to -m slow.
SHAPES = [(1, 1), (3, 5), (8, 16)] + [
    pytest.param(s, marks=pytest.mark.slow)
    for s in [(13, 64), (5, 128), (2, 200)]
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_pav_l2_kernel_matches_ref(shape, dtype):
  y = jnp.array(rng.normal(size=shape).astype(dtype))
  got = pav_l2(y)
  want = pav_l2_ref(y.astype(jnp.float32)).astype(y.dtype)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(want, np.float32),
                             atol=2e-2 if dtype == np.float16 else 2e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_pav_kl_kernel_matches_ref(shape):
  s = jnp.array(np.sort(rng.normal(size=shape), -1)[..., ::-1].copy(),
                jnp.float32)
  w = jnp.array(np.sort(rng.normal(size=shape), -1)[..., ::-1].copy(),
                jnp.float32)
  got = pav_kl(s, w)
  want = pav_kl_ref(s, w)
  np.testing.assert_allclose(got, want, atol=5e-4)


@pytest.mark.parametrize("t,e,k", [(1, 2, 1), (5, 8, 2)] + [
    pytest.param(*p, marks=pytest.mark.slow)
    for p in [(7, 64, 6), (130, 16, 3), (9, 100, 7), (256, 32, 4)]
])
def test_soft_topk_kernel_matches_ref_and_core(t, e, k):
  logits = jnp.array(rng.normal(size=(t, e)).astype(np.float32))
  got = soft_topk_gates(logits, k, 0.7)
  np.testing.assert_allclose(got, soft_topk_gates_ref(logits, k, 0.7),
                             atol=1e-4)
  np.testing.assert_allclose(got, soft_topk_mask(logits, k, 0.7),
                             atol=1e-4)
  np.testing.assert_allclose(got.sum(-1), np.full(t, k), atol=1e-3)


@pytest.mark.parametrize("n", [2, 8, 64,
                               pytest.param(128, marks=pytest.mark.slow)])
def test_bitonic_network_sorts(n):
  keys = jnp.array(rng.normal(size=(6, n)).astype(np.float32))
  payload = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (6, n))
  sk, sp = _bitonic(keys, payload, descending=True)
  np.testing.assert_allclose(sk, np.sort(np.asarray(keys), -1)[:, ::-1],
                             atol=0)
  # payload is the argsort
  np.testing.assert_array_equal(
      np.asarray(sp), np.argsort(-np.asarray(keys), -1, kind="stable"))


def test_pallas_impl_through_core_ops():
  th = jnp.array(rng.normal(size=(4, 12)).astype(np.float32))
  with use_impl("pallas"):
    r_pallas = soft_rank(th, 0.3)
  with use_impl("lax"):
    r_lax = soft_rank(th, 0.3)
  np.testing.assert_allclose(r_pallas, r_lax, atol=1e-5)


def test_grad_flows_through_pallas_forward():
  th = jnp.array(rng.normal(size=(3, 9)).astype(np.float32))
  with use_impl("pallas"):
    g = jax.grad(lambda x: jnp.sum(soft_rank(x, 0.5) ** 2))(th)
  with use_impl("lax"):
    g2 = jax.grad(lambda x: jnp.sum(soft_rank(x, 0.5) ** 2))(th)
  np.testing.assert_allclose(g, g2, atol=1e-5)

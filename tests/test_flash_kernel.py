"""Fused flash-attention Pallas kernel vs the pure-JAX reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_tpu
from repro.models.layers import flash_attention

rng = np.random.default_rng(7)


@pytest.mark.parametrize("b,sq,h,hkv,d,dv", [
    (2, 128, 4, 2, 32, 32),    # GQA
    (1, 256, 8, 8, 16, 16),    # MHA
    (2, 128, 4, 1, 32, 32),    # MQA
    (1, 128, 4, 4, 48, 24),    # dv != d (MLA-style)
])
def test_matches_reference_causal(b, sq, h, hkv, d, dv):
  q = jnp.array(rng.normal(size=(b, sq, h, d)).astype(np.float32))
  k = jnp.array(rng.normal(size=(b, sq, hkv, d)).astype(np.float32))
  v = jnp.array(rng.normal(size=(b, sq, hkv, dv)).astype(np.float32))
  got = flash_attention_tpu(q, k, v, causal=True, block_q=64, block_kv=64)
  want = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=2e-4, rtol=2e-4)


def test_non_causal():
  q = jnp.array(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
  k = jnp.array(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
  v = jnp.array(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
  got = flash_attention_tpu(q, k, v, causal=False, block_q=32, block_kv=32)
  want = flash_attention(q, k, v, causal=False, q_chunk=32, kv_chunk=32)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_bf16_io():
  q = jnp.array(rng.normal(size=(1, 64, 4, 16)), jnp.bfloat16)
  k = jnp.array(rng.normal(size=(1, 64, 2, 16)), jnp.bfloat16)
  v = jnp.array(rng.normal(size=(1, 64, 2, 16)), jnp.bfloat16)
  got = flash_attention_tpu(q, k, v, block_q=32, block_kv=32)
  assert got.dtype == jnp.bfloat16
  want = flash_attention(q, k, v, q_chunk=32, kv_chunk=32)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(want, np.float32), atol=3e-2)

"""Soft sort/rank operator semantics vs the paper's claims (Prop. 2, Lemma 3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    eps_max, eps_min, hard_rank, soft_quantile, soft_rank,
    soft_rank_kl_direct, soft_sort, soft_topk_mask)

rng = np.random.default_rng(1)


def test_paper_figure1_example():
  theta = jnp.array([2.9, 0.1, 1.2])
  # Paper Fig. 1: r(theta) = (1, 3, 2); with eps=1 (Q) the soft rank is
  # exactly the hard rank.
  np.testing.assert_allclose(soft_rank(theta, 1.0, "l2"), [1., 3., 2.],
                             atol=1e-6)


@pytest.mark.parametrize("reg", ["l2", "kl"])
def test_exact_hard_regime_below_eps_min(reg):
  """Lemma 3: for eps <= eps_min the soft operators are EXACTLY hard."""
  n = 6
  local = np.random.default_rng(42)   # deterministic: eps_min is data-dep
  theta = jnp.array(local.normal(size=n).astype(np.float32)) * 2
  rho = jnp.arange(n, 0, -1).astype(jnp.float32)
  # soft rank: z = -theta/eps, w = rho
  s_sorted = jnp.flip(jnp.sort(-theta))
  emin = float(eps_min(s_sorted, rho))
  eps = emin * 0.5
  ranks = soft_rank(theta, eps, reg)
  np.testing.assert_allclose(ranks, hard_rank(theta, "DESCENDING"),
                             atol=1e-3)
  # sort: z = rho/eps, w = sort(theta); exact for eps <= eps_min(rho, w).
  # Too-small eps costs f32 precision (z ~ rho/eps cancellation), so use
  # the largest eps inside the exact regime.
  w_sorted = jnp.flip(jnp.sort(theta))
  emin_s = float(eps_min(rho, w_sorted))
  eps_s = min(emin_s * 0.5, 0.5)
  sorted_vals = soft_sort(theta, eps_s, reg)
  np.testing.assert_allclose(
      sorted_vals, w_sorted, atol=1e-3)


def test_constant_regime_above_eps_max():
  """Lemma 3: for eps > eps_max the solution is the closed-form constant."""
  n = 5
  theta = jnp.array(rng.normal(size=n).astype(np.float32))
  rho = jnp.arange(n, 0, -1).astype(jnp.float32)
  z = -theta
  s_sorted = jnp.flip(jnp.sort(z))
  emax = float(eps_max(s_sorted, rho))
  eps = emax * 2 + 1.0
  r = soft_rank(theta, eps, "l2")
  # P_Q(z/eps, w) = z/eps - mean(z/eps - w) 1
  want = z / eps - jnp.mean(z / eps - rho)
  np.testing.assert_allclose(r, want, atol=1e-5)


@pytest.mark.parametrize("reg", ["l2", "kl"])
def test_order_preservation(reg):
  """Prop. 2.2: soft sort non-increasing; soft ranks ordered like -theta."""
  theta = jnp.array(rng.normal(size=(8, 12)).astype(np.float32))
  s = soft_sort(theta, 0.7, reg)
  assert bool(jnp.all(s[:, :-1] >= s[:, 1:] - 1e-5))
  r = soft_rank(theta, 0.7, reg)
  sigma = jnp.argsort(-theta, axis=-1)
  r_sig = jnp.take_along_axis(r, sigma, axis=-1)
  assert bool(jnp.all(r_sig[:, :-1] <= r_sig[:, 1:] + 1e-5))


def test_asymptote_large_eps():
  theta = jnp.array([0.0, 3.0, 1.0, 2.0])
  np.testing.assert_allclose(
      soft_sort(theta, 1e7), jnp.full(4, jnp.mean(theta)), atol=1e-3)
  np.testing.assert_allclose(
      soft_rank(theta, 1e7), jnp.full(4, 2.5), atol=1e-3)


def test_sum_conservation():
  """Projection lands on the permutahedron: coordinate sums are invariant."""
  theta = jnp.array(rng.normal(size=(3, 9)).astype(np.float32))
  np.testing.assert_allclose(
      jnp.sum(soft_sort(theta, 0.3), -1), jnp.sum(theta, -1), rtol=1e-4)
  np.testing.assert_allclose(
      jnp.sum(soft_rank(theta, 0.3), -1),
      jnp.full(3, 9 * 10 / 2), rtol=1e-5)


def test_directions():
  theta = jnp.array([0.0, 3.0, 1.0, 2.0])
  np.testing.assert_allclose(
      soft_rank(theta, 1e-4, direction="ASCENDING"), [1., 4., 2., 3.],
      atol=1e-3)
  np.testing.assert_allclose(
      soft_sort(theta, 1e-4, direction="ASCENDING"), [0., 1., 2., 3.],
      atol=1e-3)


def test_kl_direct_variant_hard_limit():
  theta = jnp.array([0.0, 3.0, 1.0, 2.0])
  # f32 LSE precision at theta/eps ~ 3e5 leaves ~1% residue.
  np.testing.assert_allclose(
      soft_rank_kl_direct(theta, 1e-5), [4., 1., 3., 2.], atol=5e-2)


def test_topk_mask_hard_limit_and_sum():
  theta = jnp.array([3., 1., 2., 0., -1.])
  m = soft_topk_mask(theta, 2, 1e-4)
  np.testing.assert_allclose(m, [1., 0., 1., 0., 0.], atol=1e-3)
  m2 = soft_topk_mask(theta, 2, 5.0)
  np.testing.assert_allclose(jnp.sum(m2), 2.0, rtol=1e-5)
  assert bool(jnp.all(m2 >= -1e-6)) and bool(jnp.all(m2 <= 1 + 1e-6))


def test_soft_quantile():
  x = jnp.array(rng.normal(size=101).astype(np.float32))
  q = soft_quantile(x, 0.5, 1e-3)
  np.testing.assert_allclose(q, np.median(np.array(x)), atol=1e-2)


def test_jit_vmap_grad_compose():
  theta = jnp.array(rng.normal(size=(4, 7)).astype(np.float32))

  @jax.jit
  def f(t):
    return jax.vmap(lambda row: jnp.sum(soft_rank(row, 0.5) ** 2))(t)

  g = jax.jit(jax.grad(lambda t: jnp.sum(f(t))))(theta)
  assert g.shape == theta.shape
  assert bool(jnp.all(jnp.isfinite(g)))


def test_gradients_match_fd_all_ops():
  theta = jnp.array(rng.normal(size=6).astype(np.float32))
  u = jnp.array(rng.normal(size=6).astype(np.float32))
  for fn in (lambda t: jnp.sum(soft_rank(t, 0.4) * u),
             lambda t: jnp.sum(soft_sort(t, 0.4) * u),
             lambda t: jnp.sum(soft_rank(t, 0.4, "kl") * u),
             lambda t: jnp.sum(soft_topk_mask(t, 2, 0.4) * u)):
    g = jax.grad(fn)(theta)
    eps = 1e-3
    fd = np.array([
        (fn(theta.at[i].add(eps)) - fn(theta.at[i].add(-eps))) / (2 * eps)
        for i in range(6)])
    np.testing.assert_allclose(g, fd, atol=2e-2)


def test_permutation_indices_are_int32():
  """All permutation plumbing is pinned to int32 (ISSUE 8 satellite).

  int64 indices double gather/scatter bandwidth for nothing at the sizes
  this repo targets; the fused projection residuals assume int32, so the
  hard-sort primitives must never silently widen.
  """
  from repro.core.permutations import (
      argsort_ascending, argsort_descending, inverse_permutation,
      sort_descending)
  x = jnp.array(rng.normal(size=(2, 11)).astype(np.float32))
  sigma_d = argsort_descending(x)
  sigma_a = argsort_ascending(x)
  assert sigma_d.dtype == jnp.int32
  assert sigma_a.dtype == jnp.int32
  assert inverse_permutation(sigma_d).dtype == jnp.int32
  s, sigma = sort_descending(x)
  assert sigma.dtype == jnp.int32
  np.testing.assert_array_equal(
      np.take_along_axis(np.asarray(x), np.asarray(sigma), axis=-1),
      np.asarray(s))

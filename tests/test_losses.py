"""Loss-level behavior: Spearman learning, LTS interpolation, top-k loss."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    hard_rank, soft_lts_loss, soft_spearman_loss, soft_topk_loss,
    soft_trimmed_token_loss, spearman_correlation, topk_accuracy)

rng = np.random.default_rng(2)


def test_lts_interpolates_between_trim_and_mean():
  """Paper Fig. 6: eps->0 gives hard least-trimmed mean; eps->inf gives
  the plain mean."""
  losses = jnp.array([10.0, 1.0, 2.0, 3.0])  # one outlier
  hard = soft_lts_loss(losses, trim_count=1, regularization_strength=1e-5)
  np.testing.assert_allclose(hard, np.mean([1.0, 2.0, 3.0]), atol=1e-3)
  soft = soft_lts_loss(losses, trim_count=1, regularization_strength=1e7)
  np.testing.assert_allclose(soft, np.mean([10, 1, 2, 3]), atol=1e-2)


def test_lts_gradient_downweights_outlier():
  losses_fn = lambda w: (jnp.array([10.0, 1.0, 2.0, 3.0]) * w)
  g = jax.grad(lambda w: soft_lts_loss(losses_fn(w), 1, 1e-4))(1.0)
  # gradient sees only the 3 kept losses: d/dw mean(1w,2w,3w) = 2
  np.testing.assert_allclose(g, 2.0, atol=1e-2)


def test_trimmed_token_loss_shapes():
  tl = jnp.array(rng.random((4, 64)).astype(np.float32))
  out = soft_trimmed_token_loss(tl, 0.1, 0.01)
  assert out.shape == ()
  assert float(out) < float(jnp.mean(tl)) + 1e-6


def test_spearman_loss_learns_ranking():
  """Label-ranking sanity (paper §6.3): a linear model trained with the
  soft-Spearman loss recovers the target permutation ordering."""
  d, n = 8, 5
  w_true = rng.normal(size=(d, n)).astype(np.float32)
  xs = rng.normal(size=(64, d)).astype(np.float32)
  scores = xs @ w_true
  target = np.asarray(hard_rank(jnp.array(scores), "ASCENDING"))

  w = jnp.zeros((d, n))
  xs_j, tgt = jnp.array(xs), jnp.array(target)

  def loss(w):
    return soft_spearman_loss(xs_j @ w, tgt, 1.0)

  lr = 0.05
  g_fn = jax.jit(jax.grad(loss))
  for _ in range(150):
    w = w - lr * g_fn(w)

  pred = np.asarray(hard_rank(xs_j @ w, "ASCENDING"))
  rho = np.asarray(spearman_correlation(jnp.array(pred, jnp.float32),
                                        jnp.array(target, jnp.float32)))
  assert rho.mean() > 0.9, rho.mean()


def test_topk_loss_zero_when_confident():
  theta = jnp.array([[10.0, -5.0, -5.0], [-5.0, 10.0, -5.0]])
  labels = jnp.array([0, 1])
  l = soft_topk_loss(theta, labels, k=1, regularization_strength=1e-2)
  assert float(l) < 1e-2
  assert float(topk_accuracy(theta, labels, 1)) == 1.0


def test_topk_loss_positive_when_wrong():
  theta = jnp.array([[10.0, -5.0, -5.0]])
  labels = jnp.array([2])
  l = soft_topk_loss(theta, labels, k=1, regularization_strength=1e-2)
  assert float(l) > 0.5

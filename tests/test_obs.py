"""Observability layer: metrics counters per dispatch, disabled-mode
statelessness, BENCH artifact schema round-trip, named_scope attribution
in compiled HLO, and the REPRO_BACKEND validation fix."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import soft_rank
from repro.kernels import dispatch as D
from repro.obs import artifacts, metrics

rng = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def clean_registry():
  """Each test starts from an empty, enabled registry and ends reset."""
  metrics.set_enabled(True)
  metrics.reset()
  yield
  metrics.set_enabled(None)
  metrics.reset()


# ---------------------------------------------------------------------------
# Counters increment per dispatch.
# ---------------------------------------------------------------------------


def test_counters_increment_per_dispatch():
  x = jnp.array(rng.normal(size=(3, 8)).astype(np.float32))
  for _ in range(2):
    soft_rank(x, 0.5, "l2", impl="lax")
  c = metrics.counters()
  assert c["dispatch_calls{backend=lax,op=isotonic,regularization=l2}"] == 2
  assert c["dispatch_resolve{backend=lax,op=isotonic,"
           "regularization=l2,source=arg}"] == 2
  # the identical (shape, dtype, backend) key: 1 miss then 1 hit
  assert c["dispatch_trace_cache_miss"] == 1
  assert c["dispatch_trace_cache_hit"] == 1
  # shape buckets recorded (3 rows <= 2^2, n=8 <= 2^3)
  assert c["dispatch_shape{bucket=r2^2_n2^3,op=isotonic}"] == 2


def test_plan_decide_counter_labels_kind_source_and_plan():
  from repro import plan as plan_mod
  D.resolve_backend("isotonic", "l2", None, shape=(4, 9), platform="cpu")
  D.resolve_backend("isotonic", "l2", None, shape=(4, 9), platform="tpu")
  with plan_mod.use_plan(plan_mod.ExecutionPlan(
      name="pinned", rules=(plan_mod.PlanRule("forward", "lax"),))):
    D.resolve_backend("isotonic", "l2", None, shape=(4, 9), platform="cpu")
  c = metrics.counters("plan_decide")
  # cpu routes through the committed autotuned default plan (small-n,
  # few-row cells measure fastest on lax); tpu is not measured there,
  # so it falls through to the builtin pallas rule.
  assert c["plan_decide{backend=lax,kind=forward,"
           "plan=autotuned-cpu,source=default_plan}"] == 1
  assert c["plan_decide{backend=pallas,kind=forward,"
           "plan=builtin,source=builtin}"] == 1
  assert c["plan_decide{backend=lax,kind=forward,"
           "plan=pinned,source=plan}"] == 1


# ---------------------------------------------------------------------------
# Disabled mode records no state.
# ---------------------------------------------------------------------------


def test_disabled_mode_records_no_state():
  metrics.set_enabled(False)
  x = jnp.array(rng.normal(size=(2, 6)).astype(np.float32))
  soft_rank(x, 0.5, "l2", impl="lax")
  jax.grad(lambda t: jnp.sum(soft_rank(t, 0.5, "kl", impl="minimax")))(x)
  assert metrics.counters() == {}
  assert metrics.histograms() == {}
  assert D._SEEN_TRACE_KEYS == {}
  snap = metrics.snapshot()
  assert snap == {"enabled": False, "counters": {}, "histograms": {}}


def test_disabling_drops_previously_recorded_state():
  metrics.counter_inc("x", y="z")
  assert metrics.counters()
  metrics.set_enabled(False)
  assert metrics.counters() == {}


def test_env_var_gates_metrics(monkeypatch):
  metrics.set_enabled(None)  # defer to environment
  monkeypatch.setenv(metrics.ENV_VAR, "0")
  assert not metrics.enabled()
  metrics.counter_inc("nope")
  assert metrics.counters() == {}
  monkeypatch.setenv(metrics.ENV_VAR, "1")
  assert metrics.enabled()


# ---------------------------------------------------------------------------
# Artifact schema round-trip.
# ---------------------------------------------------------------------------


def test_artifact_roundtrips_against_schema(tmp_path):
  x = jnp.array(rng.normal(size=(2, 16)).astype(np.float32))
  soft_rank(x, 0.5, "l2", impl="lax")   # populate dispatch counters
  results = [
      {"name": "t/a", "fwd_us": 12.5, "n": 16, "batch": 2,
       "backend": "lax"},
      {"name": "t/b", "skipped": "infeasible on cpu"},
      {"name": "t/c", "wall_us": 0.0},
  ]
  path = tmp_path / "BENCH_test.json"
  payload = artifacts.write_bench_artifact(
      str(path), results, artifacts.collect_meta(suite="test"))
  assert artifacts.validate_bench_payload(payload) == []
  loaded = json.loads(path.read_text())
  assert loaded == json.loads(json.dumps(payload))  # JSON-stable
  assert artifacts.validate_file(str(path)) == []
  assert loaded["schema"] == artifacts.SCHEMA_VERSION
  assert any(k.startswith("dispatch_resolve")
             for k in loaded["metrics"]["counters"])
  assert loaded["meta"]["platform"] == jax.default_backend()


@pytest.mark.parametrize("mutate,fragment", [
    (lambda p: p.pop("schema"), "schema"),
    (lambda p: p["meta"].pop("git_sha"), "git_sha"),
    (lambda p: p.pop("metrics"), "metrics"),
    (lambda p: p["results"].append({"name": "x"}), "_us"),
    (lambda p: p["results"].append({"name": "x", "fwd_us": float("nan")}),
     "finite"),
    (lambda p: p["results"].append({"fwd_us": 1.0}), "name"),
    (lambda p: p["results"].append({"name": "x", "skipped": ""}), "skipped"),
])
def test_validator_rejects_malformed_payloads(mutate, fragment):
  payload = artifacts.bench_payload(
      [{"name": "ok", "fwd_us": 1.0}], artifacts.collect_meta())
  assert artifacts.validate_bench_payload(payload) == []
  mutate(payload)
  errors = artifacts.validate_bench_payload(payload)
  assert errors and any(fragment in e for e in errors), errors


def test_writer_refuses_invalid_results(tmp_path):
  with pytest.raises(ValueError, match="refusing to write"):
    artifacts.write_bench_artifact(
        str(tmp_path / "BENCH_bad.json"), [{"name": "no-timing"}])
  assert not (tmp_path / "BENCH_bad.json").exists()


def test_validator_cli(tmp_path, capsys):
  good = tmp_path / "BENCH_good.json"
  artifacts.write_bench_artifact(str(good), [{"name": "a", "fwd_us": 1.0}])
  bad = tmp_path / "BENCH_bad.json"
  bad.write_text("{}")
  assert artifacts.main([str(good)]) == 0
  assert artifacts.main([str(good), str(bad)]) == 1
  assert artifacts.main([]) == 2


# ---------------------------------------------------------------------------
# named_scope attribution in compiled HLO for a jitted soft_rank.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["lax", "minimax"])
def test_named_scope_label_in_compiled_hlo(backend):
  from repro.obs.tracing import scope_name
  x = jnp.array(rng.normal(size=(2, 7)).astype(np.float32))
  f = jax.jit(lambda t: soft_rank(t, 0.5, "l2", impl=backend))
  hlo = f.lower(x).compile().as_text()
  assert scope_name("isotonic", "l2", backend) in hlo


def test_scope_name_is_sanitized():
  from repro.obs.tracing import scope_name
  assert scope_name("isotonic", "l2", "lax") == "repro_isotonic_l2_lax"
  assert scope_name("Iso/Tonic", "L-2", "") == "repro_iso_tonic_l_2_unknown"


# ---------------------------------------------------------------------------
# REPRO_BACKEND validation (read-time, clear error).
# ---------------------------------------------------------------------------


def test_unknown_env_backend_raises_clear_error(monkeypatch):
  monkeypatch.setenv(D.ENV_VAR, "cuda")
  with pytest.raises(ValueError, match="REPRO_BACKEND='cuda'"):
    D.resolve_backend("isotonic", "l2", None, shape=(4, 9))


def test_explicit_backend_bypasses_invalid_env(monkeypatch):
  monkeypatch.setenv(D.ENV_VAR, "bogus")
  assert D.resolve_backend("isotonic", "l2", "lax", shape=(4, 9)) == "lax"


def test_valid_env_backend_still_works(monkeypatch):
  monkeypatch.setenv(D.ENV_VAR, "minimax")
  assert D.resolve_backend("isotonic", "l2", None, shape=(4, 500)) == "minimax"


# ---------------------------------------------------------------------------
# REPRO_BACKWARD / REPRO_PROJECTION validation (same read-time contract).
# ---------------------------------------------------------------------------


def test_unknown_env_backward_raises_clear_error(monkeypatch):
  monkeypatch.setenv(D.BWD_ENV_VAR, "cuda")
  with pytest.raises(ValueError, match="REPRO_BACKWARD='cuda'"):
    D.resolve_backward("isotonic", "l2", None, shape=(4, 9))


def test_explicit_backward_bypasses_invalid_env(monkeypatch):
  monkeypatch.setenv(D.BWD_ENV_VAR, "bogus")
  assert D.resolve_backward("isotonic", "l2", "segscan",
                            shape=(4, 9)) == "segscan"


def test_valid_env_backward_still_works(monkeypatch):
  monkeypatch.setenv(D.BWD_ENV_VAR, "scatter")
  assert D.resolve_backward("isotonic", "l2", None, shape=(4, 9)) == "scatter"


def test_unknown_env_projection_raises_clear_error(monkeypatch):
  monkeypatch.setenv(D.PROJECTION_ENV_VAR, "vectorized")
  with pytest.raises(ValueError, match="REPRO_PROJECTION='vectorized'"):
    D.resolve_projection(None, "l2", shape=(4, 9))


def test_explicit_projection_bypasses_invalid_env(monkeypatch):
  monkeypatch.setenv(D.PROJECTION_ENV_VAR, "bogus")
  assert D.resolve_projection("composed", "l2", shape=(4, 9)) == "composed"


def test_valid_env_projection_still_works(monkeypatch):
  monkeypatch.setenv(D.PROJECTION_ENV_VAR, "fused")
  assert D.resolve_projection(None, "l2", shape=(4, 9)) == "fused"

"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step on CPU, asserting output shapes + finiteness; decode consistency
for representative families."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_assigned
from repro.configs.smoke import smoke_config
from repro.models import transformer as T

# Fast tier covers one dense and one MoE family; the full per-arch sweep
# runs in the slow tier (CI slow-tests job).
FAST_ARCHS = ("llama3.2-1b", "deepseek-v2-lite-16b")
ARCHS = [a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
         for a in all_assigned()]


def make_batch(cfg, key, b=2, s=32):
  ks = jax.random.split(key, 3)
  if cfg.frontend == "audio":
    return {
        "embeds": jax.random.normal(ks[0], (b, s, cfg.d_model), jnp.float32),
        "targets": jax.random.randint(
            ks[1], (b, s, cfg.num_codebooks), 0, cfg.vocab_size),
    }
  if cfg.frontend == "vision":
    st_ = s - cfg.num_patches
    return {
        "tokens": jax.random.randint(ks[0], (b, st_), 0, cfg.vocab_size),
        "image_embeds": jax.random.normal(
            ks[1], (b, cfg.num_patches, cfg.d_model), jnp.float32),
        "targets": jax.random.randint(ks[2], (b, st_), 0, cfg.vocab_size),
    }
  return {
      "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
      "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
  }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
  cfg = smoke_config(arch)
  key = jax.random.PRNGKey(0)
  params = T.init_params(cfg, key)
  batch = make_batch(cfg, key)

  losses, aux = jax.jit(lambda p, b: T.forward_train(cfg, p, b))(
      params, batch)
  tgt = batch["targets"]
  expect = tgt.shape[:2]
  assert losses.shape == expect
  assert bool(jnp.all(jnp.isfinite(losses)))
  # loss should be ~log(vocab) at init (random labels)
  assert abs(float(losses.mean()) - np.log(cfg.vocab_size)) < 2.0

  def scalar_loss(p):
    l, a = T.forward_train(cfg, p, batch)
    return jnp.mean(l) + 0.01 * a

  g = jax.jit(jax.grad(scalar_loss))(params)
  assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma3-12b",
                                  "recurrentgemma-2b", "xlstm-350m"])
def test_decode_matches_full_forward(arch):
  cfg = smoke_config(arch)
  key = jax.random.PRNGKey(1)
  params = T.init_params(cfg, key)
  b, s = 2, 24
  toks = jax.random.randint(key, (b, s + 3), 0, cfg.vocab_size)
  batch = {"tokens": toks[:, :s], "targets": toks[:, :s]}
  lg, cache = jax.jit(
      lambda p, bb: T.forward_prefill(cfg, p, bb, s + 8))(params, batch)
  dec = jax.jit(lambda p, c, t, pos: T.forward_decode(cfg, p, c, t, pos))

  def full(tokens):
    return T.forward_prefill(
        cfg, params, {"tokens": tokens, "targets": tokens},
        tokens.shape[1])[0]

  full_j = jax.jit(full)
  for i in range(3):
    lg, cache = dec(params, cache, toks[:, s + i], jnp.int32(s + i))
    ref = full_j(toks[:, :s + i + 1])
    tol = 5e-3 if arch == "xlstm-350m" else 1e-4
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref), atol=tol)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "grok-1-314b"])
def test_moe_decode_matches_with_lossless_capacity(arch):
  cfg = smoke_config(arch)
  cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
  key = jax.random.PRNGKey(1)
  params = T.init_params(cfg, key)
  b, s = 2, 16
  toks = jax.random.randint(key, (b, s + 2), 0, cfg.vocab_size)
  batch = {"tokens": toks[:, :s], "targets": toks[:, :s]}
  lg, cache = jax.jit(
      lambda p, bb: T.forward_prefill(cfg, p, bb, s + 4))(params, batch)
  dec = jax.jit(lambda p, c, t, pos: T.forward_decode(cfg, p, c, t, pos))
  for i in range(2):
    lg, cache = dec(params, cache, toks[:, s + i], jnp.int32(s + i))
    ref = T.forward_prefill(
        cfg, params,
        {"tokens": toks[:, :s + i + 1], "targets": toks[:, :s + i + 1]},
        s + i + 1)[0]
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref), atol=1e-4)


@pytest.mark.slow
def test_soft_topk_router_vs_softmax_router_gradients():
  """The paper router propagates gradient to ALL expert logits; softmax
  top-k only to the selected ones."""
  cfg = smoke_config("grok-1-314b")
  key = jax.random.PRNGKey(0)
  params = T.init_params(cfg, key)
  batch = make_batch(cfg, key, b=2, s=16)

  def router_grad(router_kind):
    c = dataclasses.replace(cfg, router=router_kind)

    def loss(p):
      l, a = T.forward_train(c, p, batch)
      return jnp.mean(l) + 0.01 * a

    g = jax.grad(loss)(params)
    flat = jax.tree_util.tree_flatten_with_path(g)[0]
    outs = []
    for path, leaf in flat:
      pstr = "/".join(str(getattr(k, "key", k)) for k in path)
      if pstr.endswith("ffn/router"):
        outs.append(np.asarray(leaf))
    return np.concatenate([o.ravel() for o in outs])

  g_soft = router_grad("soft_topk")
  g_hard = router_grad("softmax_topk")
  assert np.isfinite(g_soft).all() and np.isfinite(g_hard).all()
  # soft router should have at least as many non-zero entries
  nz_soft = np.mean(np.abs(g_soft) > 1e-12)
  nz_hard = np.mean(np.abs(g_hard) > 1e-12)
  assert nz_soft >= nz_hard

"""Elastic restart: a checkpoint written under one mesh restores onto a
different mesh shape (the fault-tolerance path for losing/gaining slices).

Runs in a subprocess so the 8-device host platform is configured before
jax initializes.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_checkpoint_restores_across_mesh_shapes():
  code = textwrap.dedent("""
    import os, json, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import checkpointer as ckpt
    from repro.launch.mesh import make_debug_mesh

    with tempfile.TemporaryDirectory() as d:
      # --- write under a (2, 4) mesh ---
      mesh_a = make_debug_mesh((2, 4), ("data", "model"))
      sh_a = NamedSharding(mesh_a, P("data", "model"))
      w = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh_a)
      tree = {"w": w, "step_scalar": jnp.float32(7)}
      ckpt.save(d, 3, tree, {"step": 3})

      # --- restore under a (4, 2) mesh, resharded ---
      mesh_b = make_debug_mesh((4, 2), ("data", "model"))
      sh_b = {"w": NamedSharding(mesh_b, P("model", "data")),
              "step_scalar": NamedSharding(mesh_b, P())}
      back, meta = ckpt.restore(d, tree, shardings=sh_b)
      ok_vals = bool(jnp.all(back["w"] == w))
      ok_shard = back["w"].sharding.is_equivalent_to(sh_b["w"], 2)
      print(json.dumps({"ok": bool(ok_vals and ok_shard),
                        "step": meta["step"]}))
  """)
  env = dict(os.environ)
  env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
  out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
  assert out.returncode == 0, out.stderr[-2000:]
  rec = json.loads(out.stdout.strip().splitlines()[-1])
  assert rec["ok"] and rec["step"] == 3

"""Isotonic solver correctness: lax PAV and minimax vs exhaustive oracle."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.isotonic import isotonic_kl, isotonic_l2
from repro.kernels.ref import pav_kl_ref, pav_l2_ref

rng = np.random.default_rng(0)


def _partitions(n):
  for cuts in itertools.product([0, 1], repeat=n - 1):
    blocks, start = [], 0
    for i, c in enumerate(cuts):
      if c:
        blocks.append((start, i + 1))
        start = i + 1
    blocks.append((start, n))
    yield blocks


def exhaustive_l2(y):
  n = len(y)
  best, bestobj = None, np.inf
  for blocks in _partitions(n):
    vals = [np.mean(y[a:b]) for a, b in blocks]
    if all(vals[i] >= vals[i + 1] - 1e-12 for i in range(len(vals) - 1)):
      v = np.concatenate([[val] * (b - a)
                          for (a, b), val in zip(blocks, vals)])
      obj = np.sum((v - y) ** 2)
      if obj < bestobj - 1e-12:
        bestobj, best = obj, v
  return best


def exhaustive_kl(s, w):
  def lse(x):
    return np.log(np.sum(np.exp(x)))
  n = len(s)
  best, bestobj = None, np.inf
  for blocks in _partitions(n):
    vals = [lse(s[a:b]) - lse(w[a:b]) for a, b in blocks]
    if all(vals[i] >= vals[i + 1] - 1e-12 for i in range(len(vals) - 1)):
      v = np.concatenate([[val] * (b - a)
                          for (a, b), val in zip(blocks, vals)])
      obj = np.sum(np.exp(s - v)) + np.sum(np.exp(w) * v)
      if obj < bestobj - 1e-12:
        bestobj, best = obj, v
  return best


@pytest.mark.parametrize("trial", range(8))
def test_l2_matches_exhaustive(trial):
  n = int(rng.integers(1, 9))
  y = rng.normal(size=n).astype(np.float32)
  want = exhaustive_l2(y.astype(np.float64))
  np.testing.assert_allclose(isotonic_l2(jnp.array(y)), want, atol=1e-5)
  np.testing.assert_allclose(pav_l2_ref(jnp.array(y)), want, atol=1e-4)
  np.testing.assert_allclose(
      isotonic_l2(jnp.array(y), "minimax"), want, atol=1e-4)
  np.testing.assert_allclose(
      isotonic_l2(jnp.array(y), "scan"), want, atol=1e-4)


@pytest.mark.parametrize("trial", range(8))
def test_kl_matches_exhaustive(trial):
  n = int(rng.integers(1, 8))
  s = np.sort(rng.normal(size=n))[::-1].copy().astype(np.float32)
  w = np.sort(rng.normal(size=n))[::-1].copy().astype(np.float32)
  want = exhaustive_kl(s.astype(np.float64), w.astype(np.float64))
  np.testing.assert_allclose(
      isotonic_kl(jnp.array(s), jnp.array(w)), want, atol=1e-4)
  np.testing.assert_allclose(
      pav_kl_ref(jnp.array(s), jnp.array(w)), want, atol=1e-4)
  np.testing.assert_allclose(
      isotonic_kl(jnp.array(s), jnp.array(w), "scan"), want, atol=1e-4)


def test_solution_is_monotone_and_preserves_block_means():
  y = jnp.array(rng.normal(size=(7, 33)).astype(np.float32))
  v = isotonic_l2(y)
  assert bool(jnp.all(v[:, :-1] >= v[:, 1:] - 1e-5))
  # KKT: total sum preserved (sum of y == sum of v for L2 isotonic)
  np.testing.assert_allclose(jnp.sum(v, -1), jnp.sum(y, -1),
                             rtol=1e-4, atol=1e-4)


def test_vjp_matches_finite_difference():
  y = jnp.array(rng.normal(size=9).astype(np.float32))
  u = jnp.array(rng.normal(size=9).astype(np.float32))

  def f(x):
    return jnp.sum(isotonic_l2(x) * u)

  g = jax.grad(f)(y)
  eps = 1e-3
  fd = np.array([(f(y.at[i].add(eps)) - f(y.at[i].add(-eps))) / (2 * eps)
                 for i in range(9)])
  np.testing.assert_allclose(g, fd, atol=2e-2)


def test_vjp_kl_matches_finite_difference():
  s = jnp.array(np.sort(rng.normal(size=7))[::-1].copy().astype(np.float32))
  w = jnp.array(np.sort(rng.normal(size=7))[::-1].copy().astype(np.float32))
  u = jnp.array(rng.normal(size=7).astype(np.float32))

  def f(a, b):
    return jnp.sum(isotonic_kl(a, b) * u)

  gs, gw = jax.grad(f, argnums=(0, 1))(s, w)
  eps = 1e-3
  for i in range(7):
    fs = (f(s.at[i].add(eps), w) - f(s.at[i].add(-eps), w)) / (2 * eps)
    fw = (f(s, w.at[i].add(eps)) - f(s, w.at[i].add(-eps))) / (2 * eps)
    assert abs(float(gs[i]) - float(fs)) < 2e-2
    assert abs(float(gw[i]) - float(fw)) < 2e-2


def test_bf16_roundtrip_dtype():
  y = jnp.array(rng.normal(size=(2, 5)), jnp.bfloat16)
  assert isotonic_l2(y).dtype == jnp.bfloat16


def test_impls_agree_large_n():
  y = jnp.array(rng.normal(size=(4, 257)).astype(np.float32))
  np.testing.assert_allclose(
      isotonic_l2(y), isotonic_l2(y, "minimax"), atol=1e-4)
  np.testing.assert_allclose(
      isotonic_l2(y, "scan"), isotonic_l2(y, "minimax"), atol=1e-4)

"""The serving pad contract: bucket-padded ops match unpadded, bitwise.

`repro.serving.ops` pads every request up to its shape bucket with a
construction that (a) sorts strictly below all real entries and (b)
never pools across the real/pad boundary, so the sliced-back result is
*bitwise* equal to the unpadded operator — per backend.  The backend
must be pinned explicitly in these tests: the precedence chain is free
to route the padded shape (B, bucket) and the unpadded shape (n,) to
different isotonic backends, and cross-backend results are only
allclose, not bit-identical.

Scalar losses (Spearman, LTS) are masked reductions over those exact
vectors; their reduce tree differs between n and bucket, so they are
checked allclose.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import soft_rank, soft_sort, soft_topk_mask
from repro.core.losses import soft_lts_loss, soft_spearman_loss
from repro.core.projection import projection_permutahedron
from repro.plan import ExecutionPlan, PlanRule
from repro.serving.ops import bound_op

try:
  from hypothesis import given, settings, strategies as st
  _HAS_HYPOTHESIS = True
except ImportError:
  _HAS_HYPOTHESIS = False

rng = np.random.default_rng(17)

BACKENDS = ["lax", "scan", "minimax"]
REGS = ["l2", "kl"]
BUCKET = 16


def _padded(values, bucket=BUCKET, fill=0.0):
  """(1, bucket) row with the real entries in the prefix.

  The pad lanes are *inputs* the construction must ignore — `fill`
  defaults to 0.0 but tests also pass garbage to prove independence.
  """
  n = values.shape[-1]
  row = np.full((1, bucket), fill, np.float32)
  row[0, :n] = values
  return jnp.asarray(row)


def _run(key, impl, values, eps, extra=None):
  """Call the padded op on one padded row; return the real prefix."""
  n = values.shape[-1]
  args = [_padded(values), jnp.array([n], jnp.int32),
          jnp.array([eps], jnp.float32)]
  if extra is not None:
    args.append(extra)
  out = bound_op(key, impl=impl)(*args)
  return np.asarray(out)[0, :n] if out.ndim == 2 else np.asarray(out)[0]


def _pin(impl):
  return ExecutionPlan(name=f"pin-{impl}", rules=(PlanRule("forward", impl),))


# ---------------------------------------------------------------------------
# Deterministic sweep: every backend x reg x op, several n, bitwise.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("reg", REGS)
@pytest.mark.parametrize("direction", ["desc", "asc"])
@pytest.mark.parametrize("n", [1, 5, 11, BUCKET])
def test_padded_soft_sort_bitwise(impl, reg, direction, n):
  v = rng.standard_normal(n).astype(np.float32) * 3
  eps = 0.7
  got = _run(f"soft_sort/{reg}/{direction}", impl, v, eps)
  dirn = "DESCENDING" if direction == "desc" else "ASCENDING"
  want = np.asarray(soft_sort(jnp.asarray(v), eps, reg, dirn, impl=impl))
  np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("reg", REGS)
@pytest.mark.parametrize("direction", ["desc", "asc"])
@pytest.mark.parametrize("n", [1, 5, 11, BUCKET])
def test_padded_soft_rank_bitwise(impl, reg, direction, n):
  v = rng.standard_normal(n).astype(np.float32) * 3
  eps = 0.7
  got = _run(f"soft_rank/{reg}/{direction}", impl, v, eps)
  dirn = "DESCENDING" if direction == "desc" else "ASCENDING"
  want = np.asarray(soft_rank(jnp.asarray(v), eps, reg, dirn, impl=impl))
  np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("reg", REGS)
@pytest.mark.parametrize("n,k", [(5, 2), (11, 1), (11, 10), (BUCKET, 4)])
def test_padded_soft_topk_bitwise(impl, reg, n, k):
  v = rng.standard_normal(n).astype(np.float32)
  eps = 0.5
  got = _run(f"soft_topk/{reg}", impl, v, eps,
             extra=jnp.array([k], jnp.int32))
  want = np.asarray(soft_topk_mask(jnp.asarray(v), k, eps, reg, impl=impl))
  np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("reg", REGS)
@pytest.mark.parametrize("n", [3, 9, BUCKET])
def test_padded_projection_bitwise(impl, reg, n):
  z = rng.standard_normal(n).astype(np.float32) * 2
  w = rng.standard_normal(n).astype(np.float32)
  if reg == "kl":
    w = np.abs(w) + 0.1
  got = _run(f"projection/{reg}", impl, z, 1.0, extra=_padded(w))
  want = np.asarray(projection_permutahedron(
      jnp.asarray(z), jnp.asarray(w), reg, impl))
  np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Scalar losses: masked reductions over exact vectors -> allclose.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("reg", REGS)
def test_padded_lts_matches_loss(impl, reg):
  v = (rng.standard_normal(9).astype(np.float32)) ** 2
  trim, eps = 3, 0.8
  got = _run(f"lts/{reg}", impl, v, eps, extra=jnp.array([trim], jnp.int32))
  want = float(soft_lts_loss(jnp.asarray(v), trim, eps, reg, plan=_pin(impl)))
  np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("reg", REGS)
def test_padded_spearman_matches_loss(impl, reg):
  v = rng.standard_normal(7).astype(np.float32)
  target = rng.permutation(7).astype(np.float32) + 1.0
  eps = 0.6
  got = _run(f"spearman/{reg}/asc", impl, v, eps, extra=_padded(target))
  want = float(soft_spearman_loss(jnp.asarray(v), jnp.asarray(target), eps,
                                  reg, direction="ASCENDING",
                                  plan=_pin(impl)))
  np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Edge cases.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", BACKENDS)
def test_padded_full_bucket_is_identity_case(impl):
  """n == bucket: no pads at all, trivially bitwise."""
  v = rng.standard_normal(BUCKET).astype(np.float32)
  got = _run("soft_rank/l2/desc", impl, v, 1.0)
  want = np.asarray(soft_rank(jnp.asarray(v), 1.0, impl=impl))
  np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("reg", REGS)
def test_padded_ties_bitwise(impl, reg):
  """Ties pool into isotonic blocks; pads must not join those blocks."""
  v = np.array([1.5, 1.5, -2.0, 1.5, -2.0], np.float32)
  for op in ("soft_sort", "soft_rank"):
    got = _run(f"{op}/{reg}/desc", impl, v, 0.9)
    ref = soft_sort if op == "soft_sort" else soft_rank
    want = np.asarray(ref(jnp.asarray(v), 0.9, reg, impl=impl))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("eps", [1e-3, 1.0, 1e3])
def test_padded_extreme_eps_bitwise(impl, eps):
  """eps near the hard-sort and constant-collapse limits."""
  v = rng.standard_normal(6).astype(np.float32)
  for reg in REGS:
    got = _run(f"soft_sort/{reg}/desc", impl, v, eps)
    want = np.asarray(soft_sort(jnp.asarray(v), eps, reg, impl=impl))
    np.testing.assert_array_equal(got, want)


def test_pad_lane_inputs_are_ignored():
  """The construction must never read the pad lanes of the input row."""
  v = rng.standard_normal(5).astype(np.float32)
  outs = []
  for fill in (0.0, 1e30, -1e30, np.nan):
    row = _padded(v, fill=fill)
    out = bound_op("soft_rank/l2/desc", impl="lax")(
        row, jnp.array([5], jnp.int32), jnp.array([0.5], jnp.float32))
    outs.append(np.asarray(out)[0, :5])
  for o in outs[1:]:
    np.testing.assert_array_equal(outs[0], o)


def test_padded_batch_rows_are_independent():
  """Rows with different true_n / eps in one batch match per-row calls."""
  ns = [2, 7, BUCKET]
  epss = [0.3, 1.0, 2.5]
  rows = [rng.standard_normal(n).astype(np.float32) for n in ns]
  batch = jnp.concatenate([_padded(v) for v in rows], axis=0)
  out = bound_op("soft_sort/l2/desc", impl="lax")(
      batch, jnp.array(ns, jnp.int32), jnp.array(epss, jnp.float32))
  for i, (v, n, eps) in enumerate(zip(rows, ns, epss)):
    want = np.asarray(soft_sort(jnp.asarray(v), eps, impl="lax"))
    np.testing.assert_array_equal(np.asarray(out)[i, :n], want)


# ---------------------------------------------------------------------------
# Property tests (hypothesis; installed via `pip install -e .[dev]`).
# ---------------------------------------------------------------------------

if _HAS_HYPOTHESIS:
  SETTINGS = dict(max_examples=25, deadline=None)

  floats = st.floats(min_value=-100, max_value=100, allow_nan=False,
                     allow_infinity=False, width=32)
  vectors = st.lists(floats, min_size=1, max_size=BUCKET)
  eps_strat = st.floats(min_value=1e-3, max_value=1e3, allow_nan=False,
                        width=32)
  backend_strat = st.sampled_from(BACKENDS)
  reg_strat = st.sampled_from(REGS)

  @given(vectors, eps_strat, backend_strat, reg_strat)
  @settings(**SETTINGS)
  def test_property_padded_soft_sort_bitwise(v, eps, impl, reg):
    arr = np.asarray(v, np.float32)
    got = _run(f"soft_sort/{reg}/desc", impl, arr, eps)
    want = np.asarray(soft_sort(jnp.asarray(arr), eps, reg, impl=impl))
    np.testing.assert_array_equal(got, want)

  @given(vectors, eps_strat, backend_strat, reg_strat)
  @settings(**SETTINGS)
  def test_property_padded_soft_rank_bitwise(v, eps, impl, reg):
    arr = np.asarray(v, np.float32)
    got = _run(f"soft_rank/{reg}/desc", impl, arr, eps)
    want = np.asarray(soft_rank(jnp.asarray(arr), eps, reg, impl=impl))
    np.testing.assert_array_equal(got, want)

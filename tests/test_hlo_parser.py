"""HLO cost-parser correctness: scan/unroll parity + synthetic fragments."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import (
    HloCostModel, _shape_bytes_elems, analyze_text, parse_computations)


def test_shape_bytes():
  assert _shape_bytes_elems("f32[4,8]{1,0}") == (128, 32)
  assert _shape_bytes_elems("bf16[10]{0}") == (20, 10)
  assert _shape_bytes_elems("(s32[], f32[2,2]{1,0})") == (20, 5)
  assert _shape_bytes_elems("pred[]") == (1, 1)


def test_scan_flops_match_unrolled():
  """The whole point of the parser: scan bodies scale by trip count."""
  def body(c, w):
    return jnp.tanh(c @ w), ()

  def f_scan(x, ws):
    c, _ = jax.lax.scan(body, x, ws)
    return jnp.sum(c)

  def f_unroll(x, ws):
    c = x
    for i in range(8):
      c = jnp.tanh(c @ ws[i])
    return jnp.sum(c)

  x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
  ws = jax.ShapeDtypeStruct((8, 32, 32), jnp.float32)
  rs = analyze_text(jax.jit(f_scan).lower(x, ws).compile().as_text())
  ru = analyze_text(jax.jit(f_unroll).lower(x, ws).compile().as_text())
  assert rs["flops_per_device"] > 0
  np.testing.assert_allclose(rs["flops_per_device"], ru["flops_per_device"],
                             rtol=0.15)
  # dot flops dominate: 8 * 2 * 32^3
  assert rs["flops_per_device"] >= 8 * 2 * 32 ** 3


def test_dot_flops_exact():
  def f(a, b):
    return a @ b

  a = jax.ShapeDtypeStruct((16, 64), jnp.float32)
  b = jax.ShapeDtypeStruct((64, 32), jnp.float32)
  r = analyze_text(jax.jit(f).lower(a, b).compile().as_text())
  want = 2 * 16 * 32 * 64
  assert abs(r["flops_per_device"] - want) / want < 0.05


def test_synthetic_while_trip_count():
  text = """
HloModule test, num_partitions=1

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%zero, %x)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
  r = analyze_text(text)
  # 12 iterations of an 8x8x8 dot (+ a few scalar ops per iteration)
  want = 12 * 2 * 8 * 8 * 8
  assert want <= r["flops_per_device"] <= want + 1000, r


def test_synthetic_collectives_counted():
  text = """
HloModule test, num_partitions=4

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128]) -> f32[128] {
  %x = f32[128]{0} parameter(0)
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %ag = f32[512]{0} all-gather(%ar), dimensions={0}
  ROOT %o = f32[128]{0} slice(%ag), slice={[0:128]}
}
"""
  r = analyze_text(text)
  assert r["collectives_by_type"]["all-reduce"] == 512
  assert r["collectives_by_type"]["all-gather"] == 512
  assert r["collective_bytes_per_device"] == 1024


def test_parse_computations_structure():
  comps = parse_computations("""
%foo (a: f32[2]) -> f32[2] {
  %a = f32[2]{0} parameter(0)
  ROOT %t = f32[2]{0} tanh(%a)
}

ENTRY %main (x: f32[2]) -> f32[2] {
  %x = f32[2]{0} parameter(0)
  ROOT %c = f32[2]{0} call(%x), to_apply=%foo
}
""")
  assert set(comps) == {"foo", "main"}
  assert comps["foo"][1].opcode == "tanh"

"""Sharding rules + a true multi-device dry-run smoke in a subprocess.

The subprocess is required because the 8-device host platform must be
configured before jax initializes (the main test process keeps 1 device).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs.smoke import smoke_config


class FakeMesh:
  def __init__(self, shape):
    self.shape = dict(shape)
    self.axis_names = tuple(shape)
    self.size = 1
    for v in shape.values():
      self.size *= v


def make_rules(fsdp=False):
  from repro.sharding.specs import ShardingRules
  mesh = FakeMesh({"data": 16, "model": 16})
  return ShardingRules(mesh, data_axes=("data",), model_axis="model",
                       fsdp=fsdp)


def test_param_rules_divisibility_fallback():
  from repro.sharding.specs import param_spec
  rules = make_rules()
  # 10 heads cannot shard over 16-way model axis -> falls back to None
  spec = param_spec(rules, "seg0/l0_local/attn/wq", (3, 2560, 10, 256))
  assert spec[2] is None
  # 32 heads can
  spec = param_spec(rules, "seg0/l0_dense/attn/wq", (3, 2560, 32, 80))
  assert spec[2] == "model"


def test_param_rules_moe_vs_dense_ffn():
  from repro.sharding.specs import param_spec
  rules = make_rules(fsdp=True)
  # routed experts (E, d, f): E=64 over model
  spec = param_spec(rules, "seg0/l0_moe/ffn/we_in", (1, 64, 2048, 1408))
  assert spec[1] == "model"
  # dense ffn (d, f): d over data (fsdp) and f over model
  spec = param_spec(rules, "seg0/l0_dense/ffn/w_in", (1, 2048, 8192))
  assert spec[2] == "model"
  # grok: 8 experts cannot take the 16-way axis -> falls to ffn dim
  spec = param_spec(rules, "seg0/l0_moe/ffn/we_in", (1, 8, 6144, 32768))
  assert spec[1] is None and spec[3] == "model"


def test_no_axis_used_twice():
  from repro.sharding.specs import ShardingRules
  rules = make_rules()
  spec = rules.spec((16, 32, 64), (("data",), ("data", "model"), None))
  # 'data' consumed by dim0 must not repeat in dim1
  flat = []
  for s in spec:
    if s is None:
      continue
    flat.extend((s,) if isinstance(s, str) else s)
  assert len(flat) == len(set(flat))


def test_cache_rules_long_context_batch1():
  from repro.sharding.specs import cache_spec
  rules = make_rules()
  # (reps, B=1, S, H, D): B unshardable -> S takes data+model (256-way)
  spec = cache_spec(rules, "seg0/l0_dense/k", (4, 1, 524288, 8, 64))
  assert spec[1] is None
  assert spec[2] is not None


def test_activation_rules_noop_without_context():
  import jax.numpy as jnp
  from repro.sharding.specs import shard_activation
  x = jnp.ones((2, 3, 4))
  y = shard_activation(x, "residual")
  assert y is x


@pytest.mark.slow
def test_subprocess_multidevice_dryrun():
  """Lower + compile a tiny arch on an 8-device (2x4) mesh end to end."""
  code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json, dataclasses
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.smoke import smoke_config
    from repro.launch.mesh import make_debug_mesh, data_axes_of
    from repro.launch import steps as ST
    from repro.models import transformer as T
    from repro.sharding import specs as SP
    from repro.optim import adamw

    cfg = smoke_config("llama3.2-1b")
    cfg = dataclasses.replace(cfg, d_model=64, num_heads=4, num_kv_heads=4)
    mesh = make_debug_mesh((2, 4), ("data", "model"))
    rules = SP.ShardingRules(mesh, data_axes=("data",), model_axis="model")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    pspecs = SP.param_specs_tree(rules, params)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    opt_cfg = adamw.AdamWConfig()
    opt = ST.init_opt_state(cfg, opt_cfg, params)
    ospecs = SP.opt_state_specs_tree(rules, opt, pspecs)
    oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                          is_leaf=lambda x: isinstance(x, P))
    batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
             "targets": jnp.zeros((8, 32), jnp.int32)}
    step = ST.make_train_step(cfg, opt_cfg)
    with mesh, SP.use_rules(rules):
      jitted = jax.jit(step, in_shardings=(pshard, oshard, None),
                       out_shardings=(pshard, oshard, None))
      params2, opt2, metrics = jitted(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), metrics
    print(json.dumps({"ok": True, "loss": float(metrics["loss"])}))
  """)
  env = dict(os.environ)
  env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
  out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
  assert out.returncode == 0, out.stderr[-2000:]
  rec = json.loads(out.stdout.strip().splitlines()[-1])
  assert rec["ok"]

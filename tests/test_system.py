"""End-to-end behaviour tests: trainer loop (checkpoint/restart, soft-LTS
robust loss), serve loop, and the paper baselines."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import allpairs_rank, ot_rank
from repro.core.losses import hard_rank


def _run(args, timeout=900):
  env = dict(os.environ)
  env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
  out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, env=env, timeout=timeout)
  assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-1500:])
  return out.stdout


@pytest.mark.slow
def test_train_loop_runs_and_loss_decreases():
  out = _run(["repro.launch.train", "--arch", "llama3.2-1b", "--smoke",
              "--steps", "40", "--batch", "8", "--seq", "64",
              "--lr", "3e-3"])
  losses = [float(l.split("loss")[1].split()[0].rstrip(";"))
            for l in out.splitlines()
            if "loss" in l and "step" in l and "[train]" in l]
  assert len(losses) >= 3
  assert losses[-1] < losses[0] - 0.1, losses


@pytest.mark.slow
def test_train_checkpoint_restart_continuity():
  with tempfile.TemporaryDirectory() as d:
    _run(["repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
          "--steps", "6", "--batch", "4", "--seq", "32",
          "--ckpt-dir", d, "--ckpt-every", "3"])
    out = _run(["repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
                "--steps", "10", "--batch", "4", "--seq", "32",
                "--ckpt-dir", d, "--ckpt-every", "3"])
    assert "resumed from step 6" in out


@pytest.mark.slow
def test_trimmed_training_with_corruption():
  """Soft-LTS trimming (paper §6.4 at token level) runs end to end."""
  out = _run(["repro.launch.train", "--arch", "llama3.2-1b", "--smoke",
              "--steps", "8", "--batch", "4", "--seq", "32",
              "--trim-frac", "0.1", "--corrupt", "0.2"])
  assert "done at step 8" in out


@pytest.mark.slow
def test_serve_loop():
  out = _run(["repro.launch.serve", "--arch", "llama3.2-1b", "--smoke",
              "--batch", "2", "--prompt-len", "16", "--gen", "4"])
  assert "tok/s" in out


def test_ot_baseline_converges_to_hard_ranks():
  theta = jnp.array([0.3, -1.2, 2.0, 0.9])
  r = ot_rank(theta, epsilon=1e-3, num_iters=400)
  np.testing.assert_allclose(
      r, hard_rank(theta, "DESCENDING"), atol=0.05)


def test_allpairs_baseline_converges_to_hard_ranks():
  theta = jnp.array([0.3, -1.2, 2.0, 0.9])
  r = allpairs_rank(theta, temperature=1e-3)
  np.testing.assert_allclose(
      r, hard_rank(theta, "DESCENDING"), atol=1e-3)


@pytest.mark.slow
def test_compressed_gradient_training_step():
  from repro.configs.smoke import smoke_config
  from repro.launch import steps as ST
  from repro.models import transformer as T
  from repro.optim import adamw

  cfg = smoke_config("llama3.2-1b")
  params = T.init_params(cfg, jax.random.PRNGKey(0))
  opt_cfg = adamw.AdamWConfig(lr=1e-3)
  opt = ST.init_opt_state(cfg, opt_cfg, params, compress_grads=True)
  step = jax.jit(ST.make_train_step(cfg, opt_cfg, compress_grads=True))
  batch = {
      "tokens": jnp.zeros((2, 32), jnp.int32),
      "targets": jnp.zeros((2, 32), jnp.int32),
  }
  p2, o2, m = step(params, opt, batch)
  assert bool(jnp.isfinite(m["loss"]))
  assert "ef_residual" in o2


@pytest.mark.slow
def test_grad_accum_equivalence():
  """grad_accum=2 must match a single full-batch step (same grads/params)."""
  import dataclasses
  from repro.configs.smoke import smoke_config
  from repro.launch import steps as ST
  from repro.models import transformer as T
  from repro.optim import adamw

  cfg1 = smoke_config("tinyllama-1.1b")
  cfg2 = dataclasses.replace(cfg1, grad_accum=2)
  params = T.init_params(cfg1, jax.random.PRNGKey(0))
  opt_cfg = adamw.AdamWConfig(lr=1e-2)
  batch = {
      "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                   cfg1.vocab_size),
      "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                    cfg1.vocab_size),
  }
  outs = []
  for cfg in (cfg1, cfg2):
    opt = ST.init_opt_state(cfg, opt_cfg, params)
    step = jax.jit(ST.make_train_step(cfg, opt_cfg))
    p2, _, _ = step(params, opt, batch)
    outs.append(p2)
  for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-4)
